//! Simulated interconnect substrate.
//!
//! In-process stand-in for the paper's InfiniBand EDR fabric: point-to-point
//! transfers pay latency + bytes/bandwidth (as real sleep time in the live
//! pipeline), and the all-reduce helper both *performs* the reduction over
//! learner gradient buffers and *charges* the ring-all-reduce cost
//! `2·(p−1)/p · bytes / link_bw`.
//!
//! Transfers are scheduled on a **link-occupancy model** (DESIGN.md §9):
//! every endpoint owns an egress [`LinkClock`] and an ingress [`LinkClock`],
//! and a transfer reserves virtual time on the sender's egress link and the
//! receiver's ingress link. Reservations on *distinct* links overlap in
//! wall time; reservations contending for the *same* link queue behind each
//! other. A transfer's completion is a single reserved instant — callers
//! sleep once, to that instant ([`TransferHandle::wait`]) — so k concurrent
//! transfers from k distinct owners cost ≈ the max of their individual
//! costs, not the sum, exactly as the paper's fabric assumption (R_c per
//! link, links in parallel; Eq. 7–8) requires.
//!
//! Only relative rates matter for the paper's phenomena (R_c ≫ R), so the
//! fabric is configured in bytes/sec alongside the storage throttle.

pub mod tcp;
pub mod transport;

use crate::fault::{
    Deadlines, FaultPlan, FaultTimeline, StallError, StallKind,
};
use crate::metrics::FabricSnapshot;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// Fabric configuration.
#[derive(Clone, Copy, Debug)]
pub struct FabricConfig {
    /// Per-link bandwidth in bytes/sec (both directions, full duplex).
    pub link_bandwidth_bps: f64,
    /// Per-message latency in seconds. Latency is propagation + software
    /// stack, not wire occupancy: it pipelines, so concurrent messages do
    /// not queue behind each other's latency.
    pub latency_s: f64,
    /// Ingress fan-in width: how many full-rate incoming transfers an
    /// endpoint's NIC complex can land concurrently. Models the multi-rail
    /// adapters of Lassen-class nodes (one rail per learner); `1` degrades
    /// to a single shared ingress wire that serializes the bandwidth term
    /// of every incoming transfer.
    pub ingress_rails: usize,
    /// If false, transfers are accounted but not slept (virtual mode for
    /// fast tests; the DES charges time instead). Link clocks still
    /// reserve occupancy, but reservation *start* times are anchored to
    /// the real request clock, so in virtual mode the queue/occupancy
    /// gauges are relative indicators (they depend on how fast the host
    /// issues transfers), and the wall-time overlap metrics are
    /// meaningful only when `real_time`. Traffic counters (bytes,
    /// messages, charged transfer time) are exact in both modes.
    pub real_time: bool,
}

impl Default for FabricConfig {
    fn default() -> Self {
        // EDR-class: ~12 GB/s per link, ~2us latency, quad-rail nodes.
        FabricConfig {
            link_bandwidth_bps: 12.0e9,
            latency_s: 2.0e-6,
            ingress_rails: 4,
            real_time: true,
        }
    }
}

/// One direction of one endpoint's link: an occupancy resource in virtual
/// time. `busy_until_ns` is the earliest instant a new reservation can
/// start; reservation is a CAS loop, so the clock is lock-free and safe to
/// hammer from every loader thread at once.
#[derive(Default)]
pub struct LinkClock {
    busy_until_ns: AtomicU64,
    /// Total time reservations spent queued behind earlier ones.
    queue_ns: AtomicU64,
    reservations: AtomicU64,
}

impl LinkClock {
    /// Reserve `occ_ns` of occupancy at or after `now_ns`; returns the
    /// reserved `(start, end)` in fabric time.
    fn reserve(&self, now_ns: u64, occ_ns: u64) -> (u64, u64) {
        loop {
            let free = self.busy_until_ns.load(Ordering::Acquire);
            let start = free.max(now_ns);
            let end = start + occ_ns;
            if self
                .busy_until_ns
                .compare_exchange_weak(
                    free,
                    end,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                if start > now_ns {
                    self.queue_ns.fetch_add(start - now_ns, Ordering::Relaxed);
                }
                self.reservations.fetch_add(1, Ordering::Relaxed);
                return (start, end);
            }
        }
    }

    pub fn queue_delay(&self) -> Duration {
        Duration::from_nanos(self.queue_ns.load(Ordering::Relaxed))
    }

    pub fn reservations(&self) -> u64 {
        self.reservations.load(Ordering::Relaxed)
    }
}

/// An endpoint's pair of directional link clocks.
#[derive(Default)]
struct Endpoint {
    egress: LinkClock,
    ingress: LinkClock,
}

/// The interconnect. Thread-safe; all learners share one instance.
pub struct Fabric {
    cfg: FabricConfig,
    /// Origin of fabric time (reservations are nanoseconds since this).
    epoch: Instant,
    /// Per-endpoint link clocks, grown on first use of an endpoint id
    /// (read-mostly: the write lock is only ever taken to grow).
    links: RwLock<Vec<Arc<Endpoint>>>,
    p2p_bytes: AtomicU64,
    p2p_messages: AtomicU64,
    allreduce_bytes: AtomicU64,
    allreduce_count: AtomicU64,
    // Transfer-time stats, lock-free (was a Mutex<Welford> — a global
    // lock on the remote hit path).
    transfer_ns_sum: AtomicU64,
    transfer_ns_max: AtomicU64,
    // Overlap accounting: serialized (charged) vs overlapped (wall) time.
    queue_delay_ns: AtomicU64,
    inflight: AtomicU64,
    inflight_peak: AtomicU64,
    busy_start_ns: AtomicU64,
    overlapped_ns: AtomicU64,
    /// Installed fault plan (DESIGN.md §11). `None` — the default — is
    /// the zero-injection path: no degradation, bit-identical to the
    /// unfaulted build. Read-mostly: one uncontended read-guard per
    /// transfer, the write lock only when (re)installing a plan.
    fault: RwLock<Option<Arc<FaultPlan>>>,
    /// Installed fault timeline (step-scheduled chaos; PR 7). Consulted
    /// at the fabric's current training step so dead/degraded windows
    /// open and close mid-run; `None` is the zero-injection path.
    timeline: RwLock<Option<Arc<FaultTimeline>>>,
    /// The trainer's global step clock, advanced monotonically via
    /// [`Fabric::observe_step`]; timeline queries without an explicit
    /// step (in-flight prefetch, monitors) read this.
    step: AtomicU64,
    /// Deadline budgets for waits on this fabric (transfers and fetch
    /// task latches). Installed once per job by the trainer.
    deadlines: RwLock<Deadlines>,
    /// Optional real-transport backend (DESIGN.md §13): when installed,
    /// the fetch path routes owner groups whose owner lives in another
    /// process through it instead of the virtual links. `None` — the
    /// default — keeps the in-process deterministic tier byte-for-byte
    /// unchanged.
    transport: RwLock<Option<Arc<dyn transport::PeerTransport>>>,
}

/// An in-flight transfer: link time is already reserved; [`wait`] sleeps
/// once, to the reserved completion instant. Dropping without waiting
/// completes the accounting immediately (the reservation stands — the
/// bytes still occupy the links — but no sleep is charged).
///
/// [`wait`]: TransferHandle::wait
#[must_use = "a transfer completes (and is slept) in TransferHandle::wait"]
pub struct TransferHandle<'a> {
    fabric: &'a Fabric,
    done_ns: u64,
    cost: Duration,
    queue_delay: Duration,
    finished: bool,
}

impl TransferHandle<'_> {
    /// The charged cost of this transfer alone (latency + bytes/bw),
    /// excluding queueing behind other transfers.
    pub fn cost(&self) -> Duration {
        self.cost
    }

    /// Time this transfer spent queued behind earlier reservations on
    /// either link.
    pub fn queue_delay(&self) -> Duration {
        self.queue_delay
    }

    /// Block (single sleep, when `real_time`) until the reserved
    /// completion instant; returns the charged cost.
    pub fn wait(mut self) -> Duration {
        self.finished = true;
        self.fabric.complete(self.done_ns, true);
        self.cost
    }

    /// Deadline-bounded [`wait`]: blocks at most `deadline` of real time.
    /// On a virtual-time fabric (`real_time: false`) a wait never blocks,
    /// so it can never miss. On a real-time fabric, if the reserved
    /// completion lies beyond the budget the caller sleeps only the
    /// budget, the transfer's accounting still completes (the reservation
    /// stands — the bytes occupied the links), and a typed
    /// [`StallError`] surfaces the miss: a dead or crawling peer becomes
    /// an error on the critical path within bounded time instead of a
    /// hang. `None` behaves exactly like [`wait`].
    ///
    /// [`wait`]: TransferHandle::wait
    pub fn wait_deadline(
        mut self,
        deadline: Option<Duration>,
    ) -> Result<Duration, StallError> {
        self.finished = true;
        let Some(budget) = deadline else {
            self.fabric.complete(self.done_ns, true);
            return Ok(self.cost);
        };
        if !self.fabric.cfg.real_time {
            self.fabric.complete(self.done_ns, false);
            return Ok(self.cost);
        }
        let now = self.fabric.now_ns();
        let remaining = Duration::from_nanos(self.done_ns.saturating_sub(now));
        if remaining <= budget {
            self.fabric.complete(self.done_ns, true);
            return Ok(self.cost);
        }
        // Sleep only the budget; complete the accounting without a second
        // sleep so the link clocks stay truthful.
        std::thread::sleep(budget);
        self.fabric.complete(self.done_ns, false);
        Err(StallError {
            kind: StallKind::Transfer,
            waited: budget,
            deadline: budget,
        })
    }
}

impl Drop for TransferHandle<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.fabric.complete(self.done_ns, false);
        }
    }
}

impl Fabric {
    pub fn new(cfg: FabricConfig) -> Self {
        assert!(cfg.ingress_rails > 0, "need at least one ingress rail");
        Fabric {
            cfg,
            epoch: Instant::now(),
            links: RwLock::new(Vec::new()),
            p2p_bytes: AtomicU64::new(0),
            p2p_messages: AtomicU64::new(0),
            allreduce_bytes: AtomicU64::new(0),
            allreduce_count: AtomicU64::new(0),
            transfer_ns_sum: AtomicU64::new(0),
            transfer_ns_max: AtomicU64::new(0),
            queue_delay_ns: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            inflight_peak: AtomicU64::new(0),
            busy_start_ns: AtomicU64::new(0),
            overlapped_ns: AtomicU64::new(0),
            fault: RwLock::new(None),
            timeline: RwLock::new(None),
            step: AtomicU64::new(0),
            deadlines: RwLock::new(Deadlines::none()),
            transport: RwLock::new(None),
        }
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    /// Install (or clear, with `None`) a fault plan. Subsequent
    /// transfers pay its per-endpoint degradations; in-flight handles
    /// keep the terms they were reserved under.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        *self.fault.write().unwrap() = plan;
    }

    /// Install (or clear) a step-scheduled fault timeline. The timeline
    /// is consulted at the fabric's current step clock (or an explicit
    /// step, on the `_at` query variants), so a kill/revive/flap window
    /// opens the moment the trainer's clock crosses it.
    pub fn set_fault_timeline(&self, timeline: Option<Arc<FaultTimeline>>) {
        *self.timeline.write().unwrap() = timeline;
    }

    /// Install the job's deadline budgets (transfer/task waits on this
    /// fabric read them; `Deadlines::none()` restores indefinite waits).
    pub fn set_deadlines(&self, d: Deadlines) {
        *self.deadlines.write().unwrap() = d;
    }

    pub fn deadlines(&self) -> Deadlines {
        *self.deadlines.read().unwrap()
    }

    /// Install (or clear) a live peer transport. Mirrors
    /// [`set_fault_plan`](Fabric::set_fault_plan): read-mostly, one
    /// uncontended read per owner group on the fetch path.
    pub fn set_transport(&self, t: Option<Arc<dyn transport::PeerTransport>>) {
        *self.transport.write().unwrap() = t;
    }

    /// The installed peer transport, if any.
    pub fn transport(&self) -> Option<Arc<dyn transport::PeerTransport>> {
        self.transport.read().unwrap().clone()
    }

    /// Advance the fabric's global step clock (monotonic max — racing
    /// learners can observe out of order without moving it backwards).
    pub fn observe_step(&self, step: u64) {
        self.step.fetch_max(step, Ordering::Relaxed);
    }

    pub fn current_step(&self) -> u64 {
        self.step.load(Ordering::Relaxed)
    }

    /// Whether endpoint `j` is dead under the static plan or under the
    /// timeline *at the current step clock* (no plan/timeline = alive).
    /// The fetch path checks this before resolving an owner group so a
    /// dead owner's claims can be evicted without issuing a doomed
    /// transfer.
    pub fn endpoint_dead(&self, j: usize) -> bool {
        self.endpoint_dead_at(j, self.current_step())
    }

    /// Step-explicit deadness query — the accounting-deterministic form:
    /// callers that know the training step a fetch belongs to get an
    /// answer that is a pure function of `(j, step)`, immune to races
    /// against the global clock.
    pub fn endpoint_dead_at(&self, j: usize, step: u64) -> bool {
        if self
            .fault
            .read()
            .unwrap()
            .as_ref()
            .map(|p| p.is_dead(j))
            .unwrap_or(false)
        {
            return true;
        }
        self.timeline
            .read()
            .unwrap()
            .as_ref()
            .map(|t| t.is_dead_at(j, step))
            .unwrap_or(false)
    }

    /// Fault-adjusted `(occupancy stretch, extra propagation ns)` for a
    /// transfer between `from` and `to`; `(1.0, 0)` with no plan. The
    /// stretch is the reciprocal of the *worst* endpoint's bandwidth
    /// scale; extra latency and jitter from both endpoints add as
    /// propagation (they pipeline, like base latency).
    fn fault_terms(&self, from: usize, to: usize) -> (f64, u64) {
        self.fault_terms_at(from, to, self.current_step())
    }

    fn fault_terms_at(&self, from: usize, to: usize, step: u64) -> (f64, u64) {
        let (mut inv_scale, mut extra_s) = (1.0f64, 0.0f64);
        if let Some(plan) = self.fault.read().unwrap().as_ref() {
            let a = plan.node(from);
            let b = plan.node(to);
            let scale =
                a.link_bw_scale.min(b.link_bw_scale).clamp(1e-9, 1.0);
            inv_scale = inv_scale.max(1.0 / scale);
            extra_s += a.extra_latency_s.max(0.0)
                + b.extra_latency_s.max(0.0)
                + plan.link_jitter_s(from)
                + plan.link_jitter_s(to);
        }
        if let Some(tl) = self.timeline.read().unwrap().as_ref() {
            let a = tl.spec_at(from, step);
            let b = tl.spec_at(to, step);
            let scale =
                a.link_bw_scale.min(b.link_bw_scale).clamp(1e-9, 1.0);
            inv_scale = inv_scale.max(1.0 / scale);
            extra_s += a.extra_latency_s.max(0.0)
                + b.extra_latency_s.max(0.0)
                + tl.link_jitter_s(from, step)
                + tl.link_jitter_s(to, step);
        }
        if extra_s <= 0.0 {
            return (inv_scale, 0);
        }
        (inv_scale, Duration::from_secs_f64(extra_s).as_nanos() as u64)
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Resolve a transfer's endpoint pair under ONE read guard (the write
    /// lock is taken only while growing the table, i.e. the first time an
    /// endpoint id is seen). Steady state: one uncontended read-lock per
    /// transfer — per owner *message*, not per sample.
    fn endpoints(&self, a: usize, b: usize) -> (Arc<Endpoint>, Arc<Endpoint>) {
        {
            let links = self.links.read().unwrap();
            if a < links.len() && b < links.len() {
                return (Arc::clone(&links[a]), Arc::clone(&links[b]));
            }
        }
        let mut links = self.links.write().unwrap();
        while links.len() <= a.max(b) {
            links.push(Arc::new(Endpoint::default()));
        }
        (Arc::clone(&links[a]), Arc::clone(&links[b]))
    }

    /// Time a point-to-point transfer of `bytes` would take on an idle
    /// fabric.
    pub fn p2p_cost(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(
            self.cfg.latency_s + bytes as f64 / self.cfg.link_bandwidth_bps,
        )
    }

    /// Begin a point-to-point transfer: reserve occupancy on `from`'s
    /// egress link and `to`'s ingress link (virtual-time CAS reservation,
    /// no lock, no sleep) and return a handle whose
    /// [`TransferHandle::wait`] sleeps once, to the reserved completion.
    ///
    /// One call = one message = one latency charge, which is what makes
    /// owner-coalescing pay: the batch fetch path sends ONE message per
    /// distinct remote owner (DESIGN.md §4), and dispatches the per-owner
    /// messages concurrently so distinct owner links overlap (§9).
    pub fn transfer_begin(
        &self,
        from: usize,
        to: usize,
        bytes: u64,
    ) -> TransferHandle<'_> {
        let (occ_scale, extra_ns) = self.fault_terms(from, to);
        self.transfer_begin_inner(from, to, bytes, occ_scale, extra_ns)
    }

    /// Fallible [`transfer_begin`]: errors (reserving nothing) when the
    /// installed fault plan declares either endpoint dead. The robust
    /// fetch path uses this so dead-owner transfers surface as per-step
    /// errors instead of occupying links that will never deliver.
    ///
    /// [`transfer_begin`]: Fabric::transfer_begin
    pub fn try_transfer_begin(
        &self,
        from: usize,
        to: usize,
        bytes: u64,
    ) -> Result<TransferHandle<'_>> {
        self.try_transfer_begin_at(from, to, bytes, self.current_step())
    }

    /// Step-explicit [`try_transfer_begin`]: deadness and degradation are
    /// evaluated at the training step the transfer belongs to, so a
    /// prefetching loader racing the global clock still gets
    /// accounting-deterministic refusals.
    ///
    /// [`try_transfer_begin`]: Fabric::try_transfer_begin
    pub fn try_transfer_begin_at(
        &self,
        from: usize,
        to: usize,
        bytes: u64,
        step: u64,
    ) -> Result<TransferHandle<'_>> {
        if self.endpoint_dead_at(from, step) {
            bail!("transfer from dead endpoint {from}");
        }
        if self.endpoint_dead_at(to, step) {
            bail!("transfer to dead endpoint {to}");
        }
        let (occ_scale, extra_ns) = self.fault_terms_at(from, to, step);
        Ok(self.transfer_begin_inner(from, to, bytes, occ_scale, extra_ns))
    }

    fn transfer_begin_inner(
        &self,
        from: usize,
        to: usize,
        bytes: u64,
        occ_scale: f64,
        extra_ns: u64,
    ) -> TransferHandle<'_> {
        let base_ns = self.p2p_cost(bytes).as_nanos() as u64;
        let latency_ns = Duration::from_secs_f64(self.cfg.latency_s)
            .as_nanos() as u64;
        // bytes/bw: the wire occupancy (latency pipelines, it never
        // queues), stretched by any injected bandwidth degradation.
        let occ_ns = ((base_ns.saturating_sub(latency_ns)) as f64
            * occ_scale) as u64;
        // Propagation: base latency plus injected latency/jitter.
        let prop_ns = latency_ns + extra_ns;
        let cost_ns = occ_ns + prop_ns;
        let cost = Duration::from_nanos(cost_ns);
        let occ_ingress_ns =
            (occ_ns as f64 / self.cfg.ingress_rails as f64) as u64;
        let now = self.now_ns();
        let (src, dst) = self.endpoints(from, to);
        let (_, egress_end) = src.egress.reserve(now, occ_ns);
        let (_, ingress_end) = dst.ingress.reserve(now, occ_ingress_ns);
        let done_ns = egress_end.max(ingress_end) + prop_ns;
        let queue_ns = (done_ns - now).saturating_sub(cost_ns);

        self.p2p_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.p2p_messages.fetch_add(1, Ordering::Relaxed);
        self.transfer_ns_sum.fetch_add(cost_ns, Ordering::Relaxed);
        self.transfer_ns_max.fetch_max(cost_ns, Ordering::Relaxed);
        self.queue_delay_ns.fetch_add(queue_ns, Ordering::Relaxed);
        let inflight = self.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        if inflight == 1 {
            // Only a begin that observed 0 opens a new busy span, and any
            // complete that will close the old one has already read
            // `busy_start_ns` before its decrement (see `complete`).
            self.busy_start_ns.store(now, Ordering::Release);
        }
        self.inflight_peak.fetch_max(inflight, Ordering::Relaxed);

        TransferHandle {
            fabric: self,
            done_ns,
            cost,
            queue_delay: Duration::from_nanos(queue_ns),
            finished: false,
        }
    }

    /// Transfer `bytes` from one learner to another, blocking until the
    /// reserved completion (when `real_time`). Returns the charged cost
    /// (excluding queueing). Equivalent to `transfer_begin(..).wait()`.
    pub fn transfer(&self, from: usize, to: usize, bytes: u64) -> Duration {
        self.transfer_begin(from, to, bytes).wait()
    }

    fn complete(&self, done_ns: u64, sleep: bool) {
        if sleep && self.cfg.real_time {
            let now = self.now_ns();
            if done_ns > now {
                std::thread::sleep(Duration::from_nanos(done_ns - now));
            }
        }
        let now = self.now_ns();
        // Read the span start BEFORE decrementing: a racing begin only
        // overwrites `busy_start_ns` after it observes our decremented 0,
        // so the last completer of a busy span always closes it against
        // the span's true start (never a freshly opened one).
        let started = self.busy_start_ns.load(Ordering::Acquire);
        if self.inflight.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.overlapped_ns
                .fetch_add(now.saturating_sub(started), Ordering::Relaxed);
        }
    }

    /// Ring all-reduce cost model: each member sends/receives
    /// `2·(p−1)/p · bytes` over its link.
    pub fn allreduce_cost(&self, bytes: u64, p: usize) -> Duration {
        if p <= 1 {
            return Duration::ZERO;
        }
        let steps = 2 * (p - 1);
        let per_link = 2.0 * (p as f64 - 1.0) / p as f64 * bytes as f64;
        Duration::from_secs_f64(
            steps as f64 * self.cfg.latency_s
                + per_link / self.cfg.link_bandwidth_bps,
        )
    }

    /// Sum-all-reduce over learner gradient buffers *in place*: every
    /// buffer ends up holding the element-wise sum. Charges (sleeps) the
    /// modeled cost once per call. Reduction order is fixed (learner 0
    /// upward) so results are bit-identical run to run.
    pub fn allreduce_sum(&self, buffers: &mut [&mut [f32]]) -> Duration {
        let p = buffers.len();
        if p == 0 {
            return Duration::ZERO;
        }
        let n = buffers[0].len();
        for b in buffers.iter() {
            assert_eq!(b.len(), n, "allreduce buffer length mismatch");
        }
        let mut acc = vec![0.0f32; n];
        for b in buffers.iter() {
            for (a, &x) in acc.iter_mut().zip(b.iter()) {
                *a += x;
            }
        }
        for b in buffers.iter_mut() {
            b.copy_from_slice(&acc);
        }
        let cost = self.allreduce_cost((n * 4) as u64, p);
        if self.cfg.real_time {
            std::thread::sleep(cost);
        }
        self.allreduce_bytes
            .fetch_add((n * 4) as u64, Ordering::Relaxed);
        self.allreduce_count.fetch_add(1, Ordering::Relaxed);
        cost
    }

    // -- metrics -----------------------------------------------------------

    pub fn p2p_bytes(&self) -> u64 {
        self.p2p_bytes.load(Ordering::Relaxed)
    }

    pub fn p2p_messages(&self) -> u64 {
        self.p2p_messages.load(Ordering::Relaxed)
    }

    pub fn allreduce_count(&self) -> u64 {
        self.allreduce_count.load(Ordering::Relaxed)
    }

    pub fn mean_transfer_s(&self) -> f64 {
        let n = self.p2p_messages.load(Ordering::Relaxed);
        if n == 0 {
            return f64::NAN;
        }
        self.transfer_ns_sum.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
    }

    /// Overlap/occupancy counters (see [`FabricSnapshot`]). The
    /// `overlapped_wall_s` busy-span is measured in real time, so the
    /// overlap ratio is meaningful only in `real_time` mode.
    pub fn snapshot(&self) -> FabricSnapshot {
        let links = self.links.read().unwrap();
        let (mut egress_q, mut ingress_q) = (0u64, 0u64);
        for ep in links.iter() {
            egress_q += ep.egress.queue_ns.load(Ordering::Relaxed);
            ingress_q += ep.ingress.queue_ns.load(Ordering::Relaxed);
        }
        FabricSnapshot {
            transfers: self.p2p_messages.load(Ordering::Relaxed),
            bytes: self.p2p_bytes.load(Ordering::Relaxed),
            serialized_transfer_s: self.transfer_ns_sum.load(Ordering::Relaxed)
                as f64
                / 1e9,
            overlapped_wall_s: self.overlapped_ns.load(Ordering::Relaxed)
                as f64
                / 1e9,
            max_transfer_s: self.transfer_ns_max.load(Ordering::Relaxed)
                as f64
                / 1e9,
            queue_delay_s: self.queue_delay_ns.load(Ordering::Relaxed) as f64
                / 1e9,
            egress_queue_s: egress_q as f64 / 1e9,
            ingress_queue_s: ingress_q as f64 / 1e9,
            inflight_peak: self.inflight_peak.load(Ordering::Relaxed),
            real_time: self.cfg.real_time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::NodeFault;

    fn virtual_fabric() -> Fabric {
        Fabric::new(FabricConfig { real_time: false, ..Default::default() })
    }

    /// Slow fabric for wall-clock overlap tests: 1 MB/s, zero-ish latency,
    /// costs in the milliseconds so scheduler noise stays negligible.
    fn slow_fabric(rails: usize) -> Fabric {
        Fabric::new(FabricConfig {
            link_bandwidth_bps: 1.0e6,
            latency_s: 1.0e-5,
            ingress_rails: rails,
            real_time: true,
        })
    }

    #[test]
    fn p2p_cost_scales_with_bytes() {
        let f = virtual_fabric();
        let small = f.p2p_cost(1024);
        let big = f.p2p_cost(1024 * 1024);
        assert!(big > small);
        // 12 GB/s: 1 MiB ≈ 87us + 2us latency.
        let expect = 2.0e-6 + (1024.0 * 1024.0) / 12.0e9;
        assert!((big.as_secs_f64() - expect).abs() < 1e-9);
    }

    #[test]
    fn transfer_accounts_traffic() {
        let f = virtual_fabric();
        f.transfer(0, 1, 1000);
        f.transfer(2, 3, 500);
        assert_eq!(f.p2p_bytes(), 1500);
        assert_eq!(f.p2p_messages(), 2);
        assert!(f.mean_transfer_s() > 0.0);
        let snap = f.snapshot();
        assert_eq!(snap.transfers, 2);
        assert_eq!(snap.bytes, 1500);
        assert!(snap.serialized_transfer_s > 0.0);
        assert!(snap.max_transfer_s >= f.p2p_cost(1000).as_secs_f64() - 1e-12);
        assert_eq!(snap.inflight_peak, 1);
    }

    #[test]
    fn distinct_owner_links_overlap_in_wall_time() {
        // 4 senders, one receiver, quad-rail ingress: wall ≈ max of the
        // individual costs (~4 ms each), nowhere near the 16 ms sum.
        let f = Arc::new(slow_fabric(4));
        let bytes = 4000u64; // 4 ms at 1 MB/s
        let serial: f64 = (1..=4).map(|_| f.p2p_cost(bytes).as_secs_f64()).sum();
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for owner in 1..=4usize {
                let f = Arc::clone(&f);
                s.spawn(move || {
                    f.transfer(owner, 0, bytes);
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        assert!(
            wall < serial * 0.6,
            "4-owner fan-in must overlap: wall={wall:.4}s serial={serial:.4}s"
        );
        let snap = f.snapshot();
        assert!(snap.inflight_peak >= 2, "peak={}", snap.inflight_peak);
        assert!(
            snap.serialized_transfer_s / snap.overlapped_wall_s > 1.5,
            "overlap ratio too low: {snap:?}"
        );
    }

    #[test]
    fn same_egress_link_queues() {
        // Two concurrent transfers from the SAME owner serialize on its
        // egress clock: wall ≈ sum, and queueing delay is recorded.
        let f = Arc::new(slow_fabric(4));
        let bytes = 3000u64; // 3 ms each
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for to in [0usize, 2] {
                let f = Arc::clone(&f);
                s.spawn(move || {
                    f.transfer(1, to, bytes);
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let single = f.p2p_cost(bytes).as_secs_f64();
        assert!(
            wall > single * 1.8,
            "same-link transfers must queue: wall={wall:.4}s single={single:.4}s"
        );
        let snap = f.snapshot();
        // The queued transfer waited ~one full occupancy (minus thread
        // spawn skew) behind the first.
        assert!(snap.queue_delay_s > single * 0.5, "{snap:?}");
        assert!(snap.egress_queue_s > 0.0, "{snap:?}");
    }

    #[test]
    fn single_rail_ingress_serializes_bandwidth() {
        // rails = 1: the receiver's one ingress wire carries every
        // incoming bandwidth term back-to-back, so a 4-owner fan-in
        // approaches the sum again (minus latency pipelining).
        let f = Arc::new(slow_fabric(1));
        let bytes = 2000u64; // 2 ms each
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for owner in 1..=4usize {
                let f = Arc::clone(&f);
                s.spawn(move || {
                    f.transfer(owner, 0, bytes);
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let single = f.p2p_cost(bytes).as_secs_f64();
        assert!(
            wall > single * 3.0,
            "single-rail fan-in must serialize: wall={wall:.4}s"
        );
    }

    #[test]
    fn transfer_begin_reserves_then_single_sleep() {
        let f = slow_fabric(4);
        let h = f.transfer_begin(1, 0, 2000); // 2 ms
        assert_eq!(h.queue_delay(), Duration::ZERO);
        let cost = h.cost();
        let t0 = Instant::now();
        let charged = h.wait();
        assert_eq!(charged, cost);
        // The sleep covers the reserved completion (allow scheduler slop).
        assert!(t0.elapsed().as_secs_f64() > cost.as_secs_f64() * 0.5);
    }

    #[test]
    fn dropped_handle_completes_accounting_without_sleep() {
        let f = slow_fabric(4);
        let t0 = Instant::now();
        drop(f.transfer_begin(1, 0, 50_000)); // 50 ms if slept
        assert!(t0.elapsed().as_secs_f64() < 0.040);
        assert_eq!(f.p2p_messages(), 1);
        let snap = f.snapshot();
        assert_eq!(snap.inflight_peak, 1);
    }

    #[test]
    fn virtual_mode_accounts_without_sleeping() {
        let f = virtual_fabric();
        let t0 = Instant::now();
        // 1 GiB at 12 GB/s would sleep ~90ms per call in real-time mode.
        for _ in 0..4 {
            f.transfer(1, 0, 1 << 30);
        }
        assert!(t0.elapsed().as_secs_f64() < 0.050, "virtual mode slept");
        assert_eq!(f.p2p_messages(), 4);
        // Back-to-back reservations on one sender's egress clock queue in
        // virtual time even though nothing sleeps (each reserves ~90 ms of
        // occupancy; the loop issues them within the elapsed bound above,
        // so at least the later ones start queued).
        assert!(f.snapshot().egress_queue_s > 0.0);
    }

    #[test]
    fn dead_endpoint_errors_without_reserving() {
        let f = virtual_fabric();
        f.set_fault_plan(Some(Arc::new(FaultPlan::single(
            0,
            4,
            1,
            NodeFault { dead: true, ..NodeFault::healthy() },
        ))));
        assert!(f.endpoint_dead(1));
        assert!(!f.endpoint_dead(2));
        assert!(f.try_transfer_begin(1, 0, 1000).is_err());
        assert!(f.try_transfer_begin(0, 1, 1000).is_err());
        assert_eq!(f.p2p_messages(), 0, "failed transfers reserve nothing");
        f.try_transfer_begin(2, 3, 1000).unwrap().wait();
        assert_eq!(f.p2p_messages(), 1);
        f.set_fault_plan(None);
        assert!(!f.endpoint_dead(1));
        f.try_transfer_begin(1, 0, 1000).unwrap().wait();
        assert_eq!(f.p2p_messages(), 2);
    }

    #[test]
    fn degraded_link_stretches_occupancy_only() {
        let f = virtual_fabric();
        let clean = f.transfer_begin(1, 0, 1 << 20).cost();
        // An all-healthy plan is bit-identical to no plan at all.
        f.set_fault_plan(Some(Arc::new(FaultPlan::healthy(4))));
        assert_eq!(f.transfer_begin(1, 0, 1 << 20).cost(), clean);
        // Halved bandwidth on one endpoint doubles the bandwidth term;
        // the latency term is propagation and does not stretch.
        f.set_fault_plan(Some(Arc::new(FaultPlan::single(
            0,
            4,
            1,
            NodeFault { link_bw_scale: 0.5, ..NodeFault::healthy() },
        ))));
        let slow = f.transfer_begin(1, 0, 1 << 20).cost();
        let lat = Duration::from_secs_f64(f.config().latency_s);
        let want = (clean - lat) * 2 + lat;
        let diff = (slow.as_secs_f64() - want.as_secs_f64()).abs();
        assert!(diff < 1e-6, "slow={slow:?} want={want:?}");
        // Untouched endpoint pairs pay the clean cost.
        assert_eq!(f.transfer_begin(2, 3, 1 << 20).cost(), clean);
    }

    #[test]
    fn extra_latency_and_jitter_add_propagation() {
        let f = virtual_fabric();
        let clean = f.transfer_begin(1, 0, 4096).cost().as_secs_f64();
        f.set_fault_plan(Some(Arc::new(FaultPlan::single(
            9,
            4,
            1,
            NodeFault {
                extra_latency_s: 0.010,
                jitter_s: 0.005,
                ..NodeFault::healthy()
            },
        ))));
        let c = f.transfer_begin(1, 0, 4096).cost().as_secs_f64();
        assert!(c >= clean + 0.010 - 1e-9, "extra latency missing: {c}");
        assert!(c < clean + 0.015 + 1e-9, "jitter out of bounds: {c}");
    }

    #[test]
    fn allreduce_sums_all_buffers() {
        let f = virtual_fabric();
        let mut a = vec![1.0f32, 2.0, 3.0];
        let mut b = vec![10.0f32, 20.0, 30.0];
        let mut c = vec![100.0f32, 200.0, 300.0];
        {
            let mut bufs: Vec<&mut [f32]> =
                vec![&mut a[..], &mut b[..], &mut c[..]];
            f.allreduce_sum(&mut bufs);
        }
        let want = [111.0f32, 222.0, 333.0];
        assert_eq!(a, want);
        assert_eq!(b, want);
        assert_eq!(c, want);
        assert_eq!(f.allreduce_count(), 1);
    }

    #[test]
    fn allreduce_cost_grows_sublinearly_in_p() {
        let f = virtual_fabric();
        let mb = 4 * 1024 * 1024;
        let c2 = f.allreduce_cost(mb, 2).as_secs_f64();
        let c64 = f.allreduce_cost(mb, 64).as_secs_f64();
        // Ring: per-link volume approaches 2x bytes; the bandwidth term is
        // bounded by 2x while the latency term grows with 2(p-1) steps.
        assert!(c64 < c2 * 3.0, "c2={c2} c64={c64}");
        assert_eq!(f.allreduce_cost(mb, 1), Duration::ZERO);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn allreduce_rejects_mismatched_buffers() {
        let f = virtual_fabric();
        let mut a = vec![0.0f32; 3];
        let mut b = vec![0.0f32; 4];
        let mut bufs: Vec<&mut [f32]> = vec![&mut a[..], &mut b[..]];
        f.allreduce_sum(&mut bufs);
    }

    #[test]
    fn timeline_opens_and_closes_dead_windows() {
        use crate::fault::FaultTimeline;
        let f = virtual_fabric();
        f.set_fault_timeline(Some(Arc::new(
            FaultTimeline::new(3, 4).kill(1, 10).revive(1, 20),
        )));
        // Step-explicit queries are pure in (node, step).
        assert!(!f.endpoint_dead_at(1, 9));
        assert!(f.endpoint_dead_at(1, 10));
        assert!(f.endpoint_dead_at(1, 19));
        assert!(!f.endpoint_dead_at(1, 20));
        assert!(f.try_transfer_begin_at(1, 0, 1000, 15).is_err());
        assert!(f.try_transfer_begin_at(0, 1, 1000, 15).is_err());
        f.try_transfer_begin_at(1, 0, 1000, 25).unwrap().wait();
        // The clockless query follows the observed step.
        f.observe_step(15);
        assert!(f.endpoint_dead(1));
        f.observe_step(20);
        assert!(!f.endpoint_dead(1));
        // The clock is monotonic: stale observations don't rewind it.
        f.observe_step(5);
        assert_eq!(f.current_step(), 20);
        f.set_fault_timeline(None);
        assert!(!f.endpoint_dead_at(1, 15));
    }

    #[test]
    fn timeline_degradation_stretches_transfers_in_window() {
        use crate::fault::FaultTimeline;
        let f = virtual_fabric();
        let clean = f.transfer_begin(1, 0, 1 << 20).cost();
        f.set_fault_timeline(Some(Arc::new(FaultTimeline::new(0, 4).at(
            8,
            1,
            NodeFault { link_bw_scale: 0.5, ..NodeFault::healthy() },
        ))));
        f.observe_step(4);
        assert_eq!(f.transfer_begin(1, 0, 1 << 20).cost(), clean);
        f.observe_step(8);
        assert!(f.transfer_begin(1, 0, 1 << 20).cost() > clean);
        // Untouched endpoint pairs stay clean even inside the window.
        assert_eq!(f.transfer_begin(2, 3, 1 << 20).cost(), clean);
    }

    #[test]
    fn wait_deadline_is_a_noop_on_virtual_fabrics() {
        let f = virtual_fabric();
        let h = f.transfer_begin(1, 0, 1 << 30);
        let cost = h.cost();
        // Virtual time never blocks, so it can never miss.
        let got = h.wait_deadline(Some(Duration::from_nanos(1))).unwrap();
        assert_eq!(got, cost);
    }

    #[test]
    fn wait_deadline_bounds_real_blocking_time() {
        let f = Fabric::new(FabricConfig {
            real_time: true,
            link_bandwidth_bps: 1e6, // 1 MB/s: 1 MiB ~ 1s on the wire
            ..Default::default()
        });
        let t0 = Instant::now();
        let err = f
            .transfer_begin(1, 0, 1 << 20)
            .wait_deadline(Some(Duration::from_millis(30)))
            .unwrap_err();
        let waited = t0.elapsed();
        assert_eq!(err.kind, crate::fault::StallKind::Transfer);
        assert!(waited >= Duration::from_millis(25), "{waited:?}");
        assert!(waited < Duration::from_millis(500), "{waited:?}");
        // The reservation still completed its accounting.
        assert_eq!(f.snapshot().transfers, 1);
        // A comfortable budget passes.
        f.transfer_begin(1, 0, 64)
            .wait_deadline(Some(Duration::from_secs(5)))
            .unwrap();
    }

    #[test]
    fn deadlines_install_and_clear() {
        let f = virtual_fabric();
        assert_eq!(f.deadlines(), Deadlines::none());
        let d = Deadlines::uniform(Duration::from_millis(250));
        f.set_deadlines(d);
        assert_eq!(f.deadlines().transfer, Some(Duration::from_millis(250)));
        f.set_deadlines(Deadlines::none());
        assert_eq!(f.deadlines().barrier, None);
    }
}
