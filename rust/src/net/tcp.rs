//! Multi-host TCP peer transport (DESIGN.md §14).
//!
//! [`TcpPeers`]/[`TcpPeerServer`] are the cross-host siblings of the
//! UDS pair in [`super::transport`]: the same [`PFETCH`]/[`PSAMP`]
//! protocol, the same [`PeerState`] health machine, the same serve
//! loop — but framed with the CRC-trailered [`Codec::Crc32`] (bytes
//! cross real networks) and addressed by `host:port` instead of socket
//! paths. On one host the workers rendezvous through per-rank address
//! files (each server binds an ephemeral loopback port and publishes
//! `peer-{rank}.addr`); across hosts the same code takes a static
//! `--peers` list, unchanged.
//!
//! Every wire decision point consults an optional [`NetChaos`]
//! injector, so torn frames, corrupted bytes, refused accepts, dropped
//! dials, and step-windowed rank partitions are all exercised by the
//! same build that ships. A partitioned or refused owner surfaces as a
//! typed [`TransportError`] that the fetch path's CAS-repair →
//! storage-fallback ladder absorbs: throughput degrades, parameters
//! stay bit-identical.

use super::transport::{
    decode_samples, serve_stream, Codec, NetTuning, PeerHealth, PeerState, PeerTransport,
    TransportError, Wire, PFETCH,
};
use crate::cache::CacheStack;
use crate::fault::netchaos::NetChaos;
use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// How a peer rank is addressed.
#[derive(Clone, Debug)]
pub enum PeerAddr {
    /// A fixed `host:port` (multi-host deployment: the `--peers` list).
    Static(String),
    /// A rendezvous file that the peer's server writes its bound
    /// address into (same-host ephemeral ports: loopback CI and the
    /// supervised multi-process mode).
    File(PathBuf),
}

struct TcpSlot {
    conn: Mutex<Option<TcpStream>>,
    state: PeerState,
}

/// TCP client: one lazily-dialed, cached connection per peer rank,
/// health-gated exactly like [`super::transport::UdsPeers`], plus a
/// partition check against the chaos injector before any dial.
pub struct TcpPeers {
    my_rank: usize,
    /// Learners per rank (global learner `l` ⇒ rank `l / g`).
    g: usize,
    addrs: Vec<PeerAddr>,
    slots: Vec<TcpSlot>,
    tuning: NetTuning,
    chaos: Option<Arc<NetChaos>>,
}

impl TcpPeers {
    pub fn new(
        my_rank: usize,
        learners_per_rank: usize,
        addrs: Vec<PeerAddr>,
        tuning: NetTuning,
    ) -> TcpPeers {
        let slots = (0..addrs.len())
            .map(|_| TcpSlot { conn: Mutex::new(None), state: PeerState::new() })
            .collect();
        TcpPeers {
            my_rank,
            g: learners_per_rank.max(1),
            addrs,
            slots,
            tuning,
            chaos: None,
        }
    }

    /// Install a chaos injector (shared with the server and the
    /// training loop, which publishes the step that gates partitions).
    pub fn set_chaos(&mut self, chaos: Option<Arc<NetChaos>>) {
        self.chaos = chaos;
    }

    /// The rendezvous file a given rank's server publishes its bound
    /// address into.
    pub fn addr_file(rendezvous: &Path, rank: usize) -> PathBuf {
        rendezvous.join(format!("peer-{rank}.addr"))
    }

    /// Health of the link to `rank` (observability + tests).
    pub fn peer_health(&self, rank: usize) -> Option<PeerHealth> {
        self.slots.get(rank).map(|s| s.state.health())
    }

    fn resolve(&self, rank: usize) -> Result<SocketAddr, TransportError> {
        let parse = |s: &str| {
            s.trim()
                .to_socket_addrs()
                .ok()
                .and_then(|mut it| it.next())
        };
        let addr = match &self.addrs[rank] {
            PeerAddr::Static(s) => parse(s),
            // An unreadable/unwritten rendezvous file means the peer
            // has not come up (or died before binding): peer-closed,
            // same as a refused dial.
            PeerAddr::File(p) => std::fs::read_to_string(p).ok().and_then(|s| parse(&s)),
        };
        addr.ok_or(TransportError::PeerClosed { peer: rank })
    }

    fn dial(
        &self,
        rank: usize,
        deadline: Option<Duration>,
    ) -> Result<TcpStream, TransportError> {
        if let Some(c) = &self.chaos {
            if c.next_connect_drop() {
                return Err(TransportError::PeerClosed { peer: rank });
            }
        }
        let addr = self.resolve(rank)?;
        let budget = deadline.unwrap_or(self.tuning.transfer_deadline);
        let stream = TcpStream::connect_timeout(&addr, budget)
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    fn exchange(
        &self,
        stream: &mut TcpStream,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
        let rank = owner / self.g;
        stream
            .set_read_timeout(deadline)
            .and_then(|_| stream.set_write_timeout(deadline))
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let mut req = Wire::new();
        req.u32(owner as u32).vec_u32(ids);
        Codec::Crc32
            .write(stream, PFETCH, &req.take())
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let (kind, payload) = Codec::Crc32
            .read(stream)
            .map_err(|e| e.classify(rank, deadline))?;
        decode_samples(kind, &payload, ids.len())
    }

    fn note_failure(&self, rank: usize, err: &TransportError) {
        let Some(slot) = self.slots.get(rank) else { return };
        match err {
            TransportError::Stall(_) => slot.state.note_stall(),
            _ => {
                let salt = ((self.my_rank as u64) << 32) | rank as u64;
                slot.state.note_disconnect(
                    salt,
                    self.tuning.reconnect_base,
                    self.tuning.reconnect_cap,
                );
            }
        }
    }
}

impl PeerTransport for TcpPeers {
    fn serves_local(&self, learner: usize) -> bool {
        learner / self.g == self.my_rank
    }

    fn fetch_from_owner(
        &self,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
        let rank = owner / self.g;
        let slot = self
            .slots
            .get(rank)
            .ok_or(TransportError::Malformed("owner rank out of range"))?;
        if let Some(c) = &self.chaos {
            // A partition refuses fail-fast WITHOUT touching the health
            // machine: the peer is alive and healthy, the *path* is
            // down. The moment the window closes, fetches resume
            // immediately — no residual backoff, and membership never
            // sees a partitioned-but-alive rank as dead.
            if c.partitioned(self.my_rank, rank) {
                return Err(TransportError::PeerClosed { peer: rank });
            }
        }
        if slot.state.is_dead() || slot.state.in_backoff() {
            return Err(TransportError::PeerClosed { peer: rank });
        }
        let mut guard = slot.conn.lock().unwrap();
        let had_cached = guard.is_some();
        if guard.is_none() {
            match self.dial(rank, deadline) {
                Ok(s) => *guard = Some(s),
                Err(e) => {
                    self.note_failure(rank, &e);
                    return Err(e);
                }
            }
        }
        let mut stream = guard.take().unwrap();
        match self.exchange(&mut stream, owner, ids, deadline) {
            Ok(out) => {
                slot.state.note_success();
                *guard = Some(stream);
                Ok(out)
            }
            Err(TransportError::PeerClosed { .. }) if had_cached => {
                // Stale cached stream (peer restarted): redial once.
                // The request is idempotent and no response byte was
                // accepted, so nothing can be double-counted.
                let out = self.dial(rank, deadline).and_then(|mut fresh| {
                    self.exchange(&mut fresh, owner, ids, deadline)
                        .map(|out| (out, fresh))
                });
                match out {
                    Ok((out, fresh)) => {
                        slot.state.note_success();
                        *guard = Some(fresh);
                        Ok(out)
                    }
                    Err(e) => {
                        self.note_failure(rank, &e);
                        Err(e)
                    }
                }
            }
            Err(e) => {
                self.note_failure(rank, &e);
                Err(e)
            }
        }
    }

    fn mark_dead(&self, rank: usize) {
        if let Some(slot) = self.slots.get(rank) {
            slot.state.mark_dead();
            *slot.conn.lock().unwrap() = None;
        }
    }

    fn mark_alive(&self, rank: usize) {
        if let Some(slot) = self.slots.get(rank) {
            slot.state.mark_alive();
            *slot.conn.lock().unwrap() = None;
        }
    }
}

/// TCP server: serves this process's learner caches over a loopback or
/// routable port, reusing the shared serve loop with the CRC codec and
/// optional chaos injection (tears/flips/delays on responses, refused
/// accepts at the listener).
pub struct TcpPeerServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpPeerServer {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral loopback
    /// port, `0.0.0.0:5555` for a routable one) and serve `caches`, a
    /// map from *global* learner id to that learner's stack.
    pub fn start(
        listen: &str,
        caches: HashMap<usize, Arc<CacheStack>>,
        chaos: Option<Arc<NetChaos>>,
    ) -> io::Result<TcpPeerServer> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = shutdown.clone();
        let caches = Arc::new(caches);
        let accept_thread = thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((mut conn, _)) => {
                        if let Some(c) = &chaos {
                            if c.next_accept_refuse() {
                                // Hang up immediately: the dialer sees
                                // a reset/EOF and enters its backoff.
                                drop(conn);
                                continue;
                            }
                        }
                        let _ = conn.set_nodelay(true);
                        let caches = caches.clone();
                        let stop = stop.clone();
                        let chaos = chaos.clone();
                        thread::spawn(move || {
                            serve_stream(
                                &mut conn,
                                &caches,
                                &stop,
                                Codec::Crc32,
                                chaos.as_deref(),
                            )
                        });
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(TcpPeerServer {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address (publish this to peers — via the rendezvous
    /// address file on one host, or operator config across hosts).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpPeerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Policy;
    use crate::fault::netchaos::{NetChaosSpec, Partition};
    use crate::storage::Sample;

    fn stack_with(ids: &[(u32, u16, Vec<u8>)]) -> Arc<CacheStack> {
        let stack = Arc::new(CacheStack::mem_only(u64::MAX, Policy::InsertOnly));
        for (id, label, bytes) in ids {
            stack.insert(Arc::new(Sample {
                id: *id,
                bytes: bytes.clone().into(),
                label: *label,
            }));
        }
        stack
    }

    fn fast_tuning() -> NetTuning {
        NetTuning {
            reconnect_base: Duration::from_micros(100),
            reconnect_cap: Duration::from_millis(2),
            ..NetTuning::default()
        }
    }

    fn serve_one(learner: usize, samples: &[(u32, u16, Vec<u8>)]) -> (TcpPeerServer, String) {
        let mut caches = HashMap::new();
        caches.insert(learner, stack_with(samples));
        let server = TcpPeerServer::start("127.0.0.1:0", caches, None).unwrap();
        let addr = server.local_addr().to_string();
        (server, addr)
    }

    #[test]
    fn tcp_serves_hits_and_misses_over_loopback() {
        let (_server, addr) = serve_one(3, &[(10, 4, vec![1, 2, 3]), (11, 5, vec![9])]);
        let peers = TcpPeers::new(
            0,
            2,
            vec![PeerAddr::Static("127.0.0.1:1".into()), PeerAddr::Static(addr)],
            fast_tuning(),
        );
        assert!(!peers.serves_local(3));
        assert!(peers.serves_local(1));
        let out = peers
            .fetch_from_owner(3, &[10, 99, 11], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((4, vec![1, 2, 3])));
        assert_eq!(out[1], None);
        assert_eq!(out[2], Some((5, vec![9])));
        assert_eq!(peers.peer_health(1), Some(PeerHealth::Connected));
        // And the cached connection is reused for a second exchange.
        let out = peers
            .fetch_from_owner(3, &[11], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((5, vec![9])));
    }

    #[test]
    fn address_file_rendezvous_resolves_the_bound_port() {
        let (_server, addr) = serve_one(1, &[(7, 2, vec![0xAA])]);
        let dir = std::env::temp_dir().join(format!(
            "dlio-tcp-rdv-{}-{:?}",
            std::process::id(),
            thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let file = TcpPeers::addr_file(&dir, 1);
        std::fs::write(&file, format!("{addr}\n")).unwrap();
        let peers = TcpPeers::new(
            0,
            1,
            vec![PeerAddr::File(TcpPeers::addr_file(&dir, 0)), PeerAddr::File(file)],
            fast_tuning(),
        );
        let out = peers
            .fetch_from_owner(1, &[7], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((2, vec![0xAA])));
        // Rank 0's file was never written: peer-closed, not a panic.
        let err = peers.fetch_from_owner(0, &[7], None).unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 0 }), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_frames_surface_typed_errors_then_recover() {
        let mut caches = HashMap::new();
        caches.insert(1usize, stack_with(&[(5, 9, vec![0xEE; 64])]));
        // Tear every second response: fetches alternate between typed
        // failures and clean recoveries through the backoff window.
        let chaos = Arc::new(NetChaos::new(NetChaosSpec {
            seed: 11,
            tear_every: 2,
            ..NetChaosSpec::default()
        }));
        let server =
            TcpPeerServer::start("127.0.0.1:0", caches, Some(chaos.clone())).unwrap();
        let addr = server.local_addr().to_string();
        let mut peers = TcpPeers::new(
            0,
            1,
            vec![PeerAddr::Static("127.0.0.1:1".into()), PeerAddr::Static(addr)],
            fast_tuning(),
        );
        peers.set_chaos(Some(chaos.clone()));
        let (mut oks, mut fails) = (0u32, 0u32);
        for _ in 0..24 {
            match peers.fetch_from_owner(1, &[5], Some(Duration::from_secs(2))) {
                Ok(out) => {
                    // A success is always the true bytes — a torn frame
                    // can fail the fetch but never corrupt a result.
                    assert_eq!(out[0], Some((9, vec![0xEE; 64])));
                    oks += 1;
                }
                Err(e) => {
                    assert!(
                        matches!(
                            e,
                            TransportError::PeerClosed { .. }
                                | TransportError::ShortRead { .. }
                                | TransportError::Stall(_)
                        ),
                        "unexpected error class: {e}"
                    );
                    fails += 1;
                }
            }
            // Let the (millisecond-scale) backoff window lapse.
            thread::sleep(Duration::from_millis(3));
        }
        assert!(oks > 0, "some fetches must survive");
        assert!(fails > 0, "some fetches must hit the tear");
        assert!(chaos.counters().tears > 0);
    }

    #[test]
    fn bit_flips_are_rejected_by_the_crc_never_accepted() {
        let mut caches = HashMap::new();
        caches.insert(1usize, stack_with(&[(5, 9, vec![0xAB; 128])]));
        let chaos = Arc::new(NetChaos::new(NetChaosSpec {
            seed: 3,
            flip_every: 1,
            ..NetChaosSpec::default()
        }));
        let server =
            TcpPeerServer::start("127.0.0.1:0", caches, Some(chaos.clone())).unwrap();
        let addr = server.local_addr().to_string();
        let peers = TcpPeers::new(
            0,
            1,
            vec![PeerAddr::Static("127.0.0.1:1".into()), PeerAddr::Static(addr)],
            fast_tuning(),
        );
        let err = peers
            .fetch_from_owner(1, &[5], Some(Duration::from_secs(2)))
            .unwrap_err();
        assert!(matches!(err, TransportError::Corrupt { .. }), "{err}");
        assert!(chaos.counters().flips >= 1);
    }

    #[test]
    fn refused_accepts_are_peer_closed_and_backoff_gated() {
        let mut caches = HashMap::new();
        caches.insert(1usize, stack_with(&[(5, 9, vec![1])]));
        let chaos = Arc::new(NetChaos::new(NetChaosSpec {
            seed: 1,
            accept_refuse_every: 1,
            ..NetChaosSpec::default()
        }));
        let server =
            TcpPeerServer::start("127.0.0.1:0", caches, Some(chaos.clone())).unwrap();
        let addr = server.local_addr().to_string();
        let peers = TcpPeers::new(
            0,
            1,
            vec![PeerAddr::Static("127.0.0.1:1".into()), PeerAddr::Static(addr)],
            NetTuning {
                reconnect_base: Duration::from_secs(5),
                reconnect_cap: Duration::from_secs(5),
                ..NetTuning::default()
            },
        );
        let err = peers
            .fetch_from_owner(1, &[5], Some(Duration::from_secs(2)))
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }), "{err}");
        assert!(chaos.counters().refused_accepts >= 1);
        // The failure opened a backoff window: the next call refuses
        // fail-fast (storage fallback) instead of dialing again.
        assert_eq!(peers.peer_health(1), Some(PeerHealth::Reconnecting));
        let before = chaos.counters().refused_accepts;
        let err = peers.fetch_from_owner(1, &[5], None).unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }));
        assert_eq!(
            chaos.counters().refused_accepts,
            before,
            "a backoff-gated fetch must not touch the network"
        );
    }

    #[test]
    fn partitions_refuse_without_poisoning_health() {
        let (_server, addr) = serve_one(1, &[(5, 9, vec![0x42])]);
        let chaos = Arc::new(NetChaos::new(NetChaosSpec {
            partitions: vec![Partition { a: 0, b: 1, from_gstep: 5, to_gstep: 10 }],
            ..NetChaosSpec::default()
        }));
        let mut peers = TcpPeers::new(
            0,
            1,
            vec![PeerAddr::Static("127.0.0.1:1".into()), PeerAddr::Static(addr)],
            NetTuning {
                // A huge backoff base: if the partition wrongly entered
                // the health machine, recovery below would hang.
                reconnect_base: Duration::from_secs(30),
                reconnect_cap: Duration::from_secs(30),
                ..NetTuning::default()
            },
        );
        peers.set_chaos(Some(chaos.clone()));
        chaos.observe_step(6);
        let err = peers
            .fetch_from_owner(1, &[5], Some(Duration::from_secs(2)))
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }), "{err}");
        assert_eq!(
            peers.peer_health(1),
            Some(PeerHealth::Connected),
            "a partition is a path failure, not a peer-health event"
        );
        // Window closes: the very next fetch succeeds with no residual
        // backoff and membership never saw the rank as dead.
        chaos.observe_step(10);
        let out = peers
            .fetch_from_owner(1, &[5], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((9, vec![0x42])));
        assert!(chaos.counters().partitioned_fetches >= 1);
    }
}
