//! Real-transport backends for owner-to-owner sample transfers
//! (DESIGN.md §13/§14).
//!
//! The in-process [`Fabric`](super::Fabric) stays the fast deterministic
//! tier: virtual-time link clocks, no syscalls, bit-identical accounting.
//! This module adds the live tier used by the supervised multi-process
//! mode: each learner-group process serves its cache over a Unix-domain
//! socket (same host) or TCP ([`crate::net::tcp`], multi-host) with a
//! length-prefixed frame codec, and the fetch path routes any owner
//! group whose owner lives in *another* process through a
//! [`PeerTransport`] installed on the fabric. Deadlines map onto the
//! existing [`fault::Deadlines`](crate::fault::Deadlines) budgets: a
//! read/write that exceeds its budget surfaces as a
//! [`StallError`](crate::fault::StallError) with [`StallKind::Transfer`],
//! indistinguishable (by design) from an in-process transfer stall, so
//! the PR 7 recovery path — evict claims, fall back to storage, mark the
//! peer dead — handles both tiers with one code path.
//!
//! ## Frame formats
//!
//! Every message on every socket (peer and control) is one frame. The
//! plain codec (UDS — the kernel guarantees stream integrity):
//!
//! ```text
//! [len: u32 LE] [kind: u8] [payload: len-1 bytes]
//! ```
//!
//! The CRC codec (TCP — bytes cross real, lossy networks) appends a
//! CRC-32 (ISO-HDLC) trailer over the kind byte plus payload:
//!
//! ```text
//! [len: u32 LE] [kind: u8] [payload: len-1 bytes] [crc32: u32 LE]
//! ```
//!
//! `len` counts the kind byte plus the payload (never the trailer) and
//! is capped at [`MAX_FRAME`]; a frame that announces more is a typed
//! [`TransportError::FrameTooLarge`], not a reason to allocate. A frame
//! that ends early is a typed [`TransportError::ShortRead`]; a frame
//! whose trailer disagrees with its bytes is a typed
//! [`TransportError::Corrupt`]. None of them is ever a panic or a
//! silently-accepted corruption. Multi-byte integers inside payloads are
//! little-endian (see [`Wire`]/[`WireReader`]).
//!
//! ## Peer health (DESIGN.md §14)
//!
//! Every live transport tracks one [`PeerState`] per peer rank:
//!
//! ```text
//! Connected ──stall──▶ Degraded ──disconnect──▶ Reconnecting ──┐
//!     ▲  ▲                                          │ backoff  │
//!     │  └────────────── success ◀──────────────────┘          │
//!     └── mark_alive (epoch-boundary rejoin)    mark_dead ──▶ Dead
//! ```
//!
//! Only the membership layer moves a peer to `Dead` (and only
//! `mark_alive` revives it — clearing the failure counter, the backoff
//! deadline, *and* the stale cached connection, so a revived peer is
//! redialed fresh instead of refused forever). `Reconnecting` peers are
//! refused fail-fast while their jittered-exponential backoff window
//! (the PR 7 retry policy, [`crate::fault::backoff_with`]) is open; the
//! caller's CAS-repair → storage-fallback path turns that refusal into
//! degraded throughput, never an error.
//!
//! ## Shared-memory ring (feature `shm-ring`)
//!
//! Behind the `shm-ring` feature the server can place sample payloads in
//! a preallocated mmap-shared segment and answer with (offset, len)
//! descriptors instead of inline bytes; the client maps the same file
//! and constructs zero-copy [`SampleBytes`](crate::storage::SampleBytes)
//! views, reusing the PR 5 spill-segment machinery. When the ring is
//! full the server transparently falls back to inline frames, so the
//! ring is an optimization, never a correctness dependency.

use crate::cache::CacheStack;
use crate::fault::netchaos::NetChaos;
use crate::fault::{backoff_with, StallError, StallKind};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Hard cap on a single frame (header-declared), peer and control alike.
pub const MAX_FRAME: usize = 64 << 20;

/// Peer protocol frame kinds (control-plane kinds live in
/// `coordinator::service`).
pub const PFETCH: u8 = 20;
pub const PSAMP: u8 = 21;
#[cfg(feature = "shm-ring")]
pub const PSAMP_SHM: u8 = 22;

/// Which transport backs cross-process owner fetches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Threads in one process over the virtual fabric (no transport
    /// installed) — the deterministic tier.
    InProc,
    /// Unix-domain sockets with inline frame payloads.
    Uds,
    /// TCP sockets with CRC-trailered frames — same host (loopback) or
    /// multi-host, unchanged.
    Tcp,
    /// UDS control frames + shared-memory payload ring (`shm-ring`
    /// feature; falls back to inline frames when the ring is full).
    #[cfg(feature = "shm-ring")]
    Shm,
}

impl TransportKind {
    /// Parse a `--transport` CLI value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inproc" | "threads" => Some(TransportKind::InProc),
            "uds" => Some(TransportKind::Uds),
            "tcp" => Some(TransportKind::Tcp),
            #[cfg(feature = "shm-ring")]
            "shm" => Some(TransportKind::Shm),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
            #[cfg(feature = "shm-ring")]
            TransportKind::Shm => "shm",
        }
    }
}

/// Transport-layer failure, already classified for the recovery path.
#[derive(Debug)]
pub enum TransportError {
    /// A read/write/connect exceeded its deadline budget. Carries the
    /// same [`StallError`] the in-process fabric raises, so stall
    /// accounting and exit-code mapping see one taxonomy.
    Stall(StallError),
    /// The peer's socket reached EOF (or refused the connection): the
    /// process died or was killed. Routed into the membership path.
    PeerClosed { peer: usize },
    /// A frame header declared more than [`MAX_FRAME`] bytes — either a
    /// corrupted length word or a peer speaking another protocol. Never
    /// a reason to allocate.
    FrameTooLarge { declared: u64 },
    /// A frame ended early: the stream died (or timed out) mid-frame
    /// after `got` of `needed` body bytes. A torn frame is always
    /// distinguishable from a clean close at a frame boundary.
    ShortRead { needed: usize, got: usize, timed_out: bool },
    /// The CRC trailer disagrees with the frame bytes: corruption on the
    /// wire (or a torn write spliced with a later frame). `expected` is
    /// the locally computed checksum, `got` the trailer.
    Corrupt { expected: u32, got: u32 },
    /// Any other socket-level error.
    Io(io::Error),
    /// The peer spoke, but not the protocol.
    Malformed(&'static str),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Stall(s) => write!(f, "{s}"),
            TransportError::PeerClosed { peer } => {
                write!(f, "peer process {peer} closed the connection")
            }
            TransportError::FrameTooLarge { declared } => {
                write!(f, "frame header declares {declared} bytes (cap {MAX_FRAME})")
            }
            TransportError::ShortRead { needed, got, timed_out } => {
                let how = if *timed_out { "timed out" } else { "hit eof" };
                write!(f, "short read: {how} after {got} of {needed} frame bytes")
            }
            TransportError::Corrupt { expected, got } => {
                write!(f, "frame crc mismatch: computed {expected:#010x}, trailer {got:#010x}")
            }
            TransportError::Io(e) => write!(f, "transport i/o error: {e}"),
            TransportError::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl TransportError {
    /// Classify an `io::Error` from a deadlined socket operation on the
    /// link to `peer`: timeouts become transfer stalls charged at the
    /// full budget, EOF becomes peer death.
    pub(crate) fn from_io(e: io::Error, peer: usize, deadline: Option<Duration>) -> TransportError {
        match e.kind() {
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                TransportError::Stall(transfer_stall(deadline))
            }
            io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionRefused
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::NotFound => TransportError::PeerClosed { peer },
            _ => TransportError::Io(e),
        }
    }

    /// Classify a raw codec error for the recovery path on the link to
    /// `peer`: timeouts (idle or mid-frame) become transfer stalls, EOF
    /// and torn frames become peer death, everything else passes
    /// through already typed.
    pub fn classify(self, peer: usize, deadline: Option<Duration>) -> TransportError {
        match self {
            TransportError::Io(e) => TransportError::from_io(e, peer, deadline),
            TransportError::ShortRead { timed_out: true, .. } => {
                TransportError::Stall(transfer_stall(deadline))
            }
            TransportError::ShortRead { .. } => TransportError::PeerClosed { peer },
            other => other,
        }
    }
}

fn transfer_stall(deadline: Option<Duration>) -> StallError {
    let budget = deadline.unwrap_or(Duration::ZERO);
    StallError { kind: StallKind::Transfer, waited: budget, deadline: budget }
}

// ---------------------------------------------------------------------------
// Frame codecs
// ---------------------------------------------------------------------------

/// Fill `buf`, retrying `EINTR` and accumulating partial reads. At a
/// frame boundary (`at_boundary`, i.e. the first header byte), zero
/// bytes followed by EOF is a *clean* close (`Io(UnexpectedEof)`) and
/// zero bytes followed by a timeout is an *idle* poll (`Io(WouldBlock)`
/// / `Io(TimedOut)`, the caller may keep polling). Anywhere else, both
/// are a torn frame: a typed [`TransportError::ShortRead`].
fn read_full(r: &mut impl Read, buf: &mut [u8], at_boundary: bool) -> Result<(), TransportError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && at_boundary {
                    return Err(TransportError::Io(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "eof at frame boundary",
                    )));
                }
                return Err(TransportError::ShortRead {
                    needed: buf.len(),
                    got,
                    timed_out: false,
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if got == 0 && at_boundary {
                    return Err(TransportError::Io(e));
                }
                return Err(TransportError::ShortRead {
                    needed: buf.len(),
                    got,
                    timed_out: true,
                });
            }
            Err(e) => return Err(TransportError::Io(e)),
        }
    }
    Ok(())
}

/// Validate a frame header's declared length.
fn frame_len(len4: [u8; 4]) -> Result<usize, TransportError> {
    let len = u32::from_le_bytes(len4) as u64;
    if len == 0 {
        return Err(TransportError::Malformed("bad frame length"));
    }
    if len > MAX_FRAME as u64 {
        return Err(TransportError::FrameTooLarge { declared: len });
    }
    Ok(len as usize)
}

/// Write one plain `[len][kind][payload]` frame.
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
    let len = payload.len() + 1;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidInput, "frame too large"));
    }
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one plain frame. EOF at a frame boundary surfaces as
/// `Io(UnexpectedEof)` (the caller decides whether that boundary was
/// clean); every other failure is a typed [`TransportError`].
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>), TransportError> {
    let mut len4 = [0u8; 4];
    read_full(r, &mut len4, true)?;
    let len = frame_len(len4)?;
    let mut body = vec![0u8; len];
    read_full(r, &mut body, false)?;
    let payload = body.split_off(1);
    Ok((body[0], payload))
}

/// CRC-32 (ISO-HDLC, reflected polynomial `0xEDB88320`) lookup table,
/// built at compile time — the variant every zlib/ethernet stack uses,
/// so the check value for `b"123456789"` is the canonical `0xCBF43926`.
const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

fn crc32_feed(mut c: u32, data: &[u8]) -> u32 {
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

/// CRC-32/ISO-HDLC of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    !crc32_feed(!0u32, data)
}

/// Write one CRC-trailered `[len][kind][payload][crc32]` frame; the
/// trailer covers the kind byte plus the payload.
pub fn write_frame_crc(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
    let len = payload.len() + 1;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidInput, "frame too large"));
    }
    let crc = !crc32_feed(crc32_feed(!0u32, &[kind]), payload);
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.write_all(&crc.to_le_bytes())?;
    w.flush()
}

/// Read one CRC-trailered frame; a trailer mismatch is a typed
/// [`TransportError::Corrupt`], never silently-accepted corruption.
pub fn read_frame_crc(r: &mut impl Read) -> Result<(u8, Vec<u8>), TransportError> {
    let mut len4 = [0u8; 4];
    read_full(r, &mut len4, true)?;
    let len = frame_len(len4)?;
    let mut body = vec![0u8; len + 4];
    read_full(r, &mut body, false)?;
    let trailer = u32::from_le_bytes(body[len..].try_into().unwrap());
    let computed = crc32(&body[..len]);
    if computed != trailer {
        return Err(TransportError::Corrupt { expected: computed, got: trailer });
    }
    body.truncate(len);
    let payload = body.split_off(1);
    Ok((body[0], payload))
}

/// Which frame codec a stream speaks: plain for kernel-checked local
/// streams (UDS), CRC-trailered for bytes that cross real networks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    Plain,
    Crc32,
}

impl Codec {
    pub fn write(self, w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
        match self {
            Codec::Plain => write_frame(w, kind, payload),
            Codec::Crc32 => write_frame_crc(w, kind, payload),
        }
    }

    pub fn read(self, r: &mut impl Read) -> Result<(u8, Vec<u8>), TransportError> {
        match self {
            Codec::Plain => read_frame(r),
            Codec::Crc32 => read_frame_crc(r),
        }
    }
}

/// Little-endian payload writer.
#[derive(Default)]
pub struct Wire(Vec<u8>);

impl Wire {
    pub fn new() -> Wire {
        Wire(Vec::new())
    }
    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.0.push(v);
        self
    }
    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.0.extend_from_slice(v);
        self
    }
    /// Length-prefixed `u32` vector.
    pub fn vec_u32(&mut self, v: &[u32]) -> &mut Self {
        self.u32(v.len() as u32);
        for x in v {
            self.u32(*x);
        }
        self
    }
    /// Length-prefixed `f32` vector.
    pub fn vec_f32(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
        self
    }
    pub fn take(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.0)
    }
}

/// Bounds-checked little-endian payload reader; every decoder error is a
/// typed [`TransportError::Malformed`], never a panic.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn need(&mut self, n: usize) -> Result<&'a [u8], TransportError> {
        if self.buf.len() - self.pos < n {
            return Err(TransportError::Malformed("payload truncated"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, TransportError> {
        Ok(self.need(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16, TransportError> {
        Ok(u16::from_le_bytes(self.need(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32, TransportError> {
        Ok(u32::from_le_bytes(self.need(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64, TransportError> {
        Ok(u64::from_le_bytes(self.need(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32, TransportError> {
        Ok(f32::from_le_bytes(self.need(4)?.try_into().unwrap()))
    }
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], TransportError> {
        self.need(n)
    }
    pub fn vec_u32(&mut self) -> Result<Vec<u32>, TransportError> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 4 {
            return Err(TransportError::Malformed("u32 vector over-long"));
        }
        (0..n).map(|_| self.u32()).collect()
    }
    pub fn vec_f32(&mut self) -> Result<Vec<f32>, TransportError> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 4 {
            return Err(TransportError::Malformed("f32 vector over-long"));
        }
        (0..n).map(|_| self.f32()).collect()
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

// ---------------------------------------------------------------------------
// Peer health state machine (DESIGN.md §14)
// ---------------------------------------------------------------------------

/// Connection-pool health of one peer rank. See the module docs for the
/// transition diagram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerHealth {
    /// Last exchange succeeded (or the peer has never been dialed).
    Connected,
    /// The peer answers but blew a deadline: served, but slow.
    Degraded,
    /// The connection dropped; redials are gated by jittered
    /// exponential backoff and refused fail-fast while it is open.
    Reconnecting,
    /// Excised by the membership layer. Only [`PeerState::mark_alive`]
    /// (an epoch-boundary rejoin) leaves this state.
    Dead,
}

const H_CONNECTED: u8 = 0;
const H_DEGRADED: u8 = 1;
const H_RECONNECTING: u8 = 2;
const H_DEAD: u8 = 3;

/// Shared per-peer connection health: the one state machine behind both
/// [`UdsPeers`] and [`crate::net::tcp::TcpPeers`]. Failure observations
/// never promote a peer to `Dead` on their own — only the membership
/// path does that — so a flaky-but-alive peer degrades to backoff-gated
/// reconnects (and storage fallback in between), while a truly dead one
/// is excised exactly once, by the coordinator.
pub struct PeerState {
    health: AtomicU8,
    failures: AtomicU32,
    retry_at: Mutex<Option<Instant>>,
}

impl Default for PeerState {
    fn default() -> Self {
        PeerState::new()
    }
}

impl PeerState {
    pub fn new() -> PeerState {
        PeerState {
            health: AtomicU8::new(H_CONNECTED),
            failures: AtomicU32::new(0),
            retry_at: Mutex::new(None),
        }
    }

    pub fn health(&self) -> PeerHealth {
        match self.health.load(Ordering::Acquire) {
            H_DEGRADED => PeerHealth::Degraded,
            H_RECONNECTING => PeerHealth::Reconnecting,
            H_DEAD => PeerHealth::Dead,
            _ => PeerHealth::Connected,
        }
    }

    pub fn is_dead(&self) -> bool {
        self.health.load(Ordering::Acquire) == H_DEAD
    }

    /// Consecutive failures since the last success (drives the backoff
    /// exponent).
    pub fn failures(&self) -> u32 {
        self.failures.load(Ordering::Relaxed)
    }

    /// True while a `Reconnecting` peer's backoff window is still open:
    /// the caller should refuse fail-fast instead of dialing.
    pub fn in_backoff(&self) -> bool {
        if self.health.load(Ordering::Acquire) != H_RECONNECTING {
            return false;
        }
        matches!(*self.retry_at.lock().unwrap(), Some(t) if Instant::now() < t)
    }

    fn set_unless_dead(&self, h: u8) {
        // A racing mark_dead wins: membership is authoritative.
        let mut cur = self.health.load(Ordering::Acquire);
        while cur != H_DEAD && cur != h {
            match self.health.compare_exchange_weak(
                cur,
                h,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// An exchange completed: back to `Connected`, counter and backoff
    /// cleared (unless membership declared the peer dead meanwhile).
    pub fn note_success(&self) {
        self.failures.store(0, Ordering::Relaxed);
        *self.retry_at.lock().unwrap() = None;
        self.set_unless_dead(H_CONNECTED);
    }

    /// An exchange blew its deadline but the connection may be fine:
    /// `Degraded`, no backoff (the per-call deadline already bounds the
    /// damage).
    pub fn note_stall(&self) {
        self.set_unless_dead(H_DEGRADED);
    }

    /// The connection dropped (EOF, refused dial, torn frame):
    /// `Reconnecting`, with the next dial gated by jittered exponential
    /// backoff — attempt k waits `base·2^k` ± 25%, capped at `cap`
    /// (the PR 7 retry policy, [`backoff_with`]).
    pub fn note_disconnect(&self, salt: u64, base: Duration, cap: Duration) {
        let n = self.failures.fetch_add(1, Ordering::Relaxed).saturating_add(1);
        let wait = backoff_with(n as usize, salt, base.as_micros() as u64, cap);
        *self.retry_at.lock().unwrap() = Some(Instant::now() + wait.min(cap));
        self.set_unless_dead(H_RECONNECTING);
    }

    /// Membership hook: the peer was excised. Terminal until
    /// [`PeerState::mark_alive`].
    pub fn mark_dead(&self) {
        self.health.store(H_DEAD, Ordering::Release);
    }

    /// Membership hook: the peer rejoined at an epoch boundary. Clears
    /// the dead mark, the failure counter, *and* the backoff deadline —
    /// a revived peer starts from a clean slate instead of inheriting
    /// the backoff (or refusal) earned by its previous incarnation.
    pub fn mark_alive(&self) {
        self.failures.store(0, Ordering::Relaxed);
        *self.retry_at.lock().unwrap() = None;
        self.health.store(H_CONNECTED, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// Validated network tuning (satellite: TrainerConfig surface)
// ---------------------------------------------------------------------------

/// Network-layer tuning knobs, validated at the configuration boundary
/// (like `LoaderConfig::normalized()`): zero or absurd values are
/// rejected before any socket is opened, not discovered mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NetTuning {
    /// Worker heartbeat send period.
    pub hb_interval: Duration,
    /// Coordinator silence budget before a rank is declared dead.
    pub hb_timeout: Duration,
    /// Per-call budget for one peer fetch exchange.
    pub transfer_deadline: Duration,
    /// Base of the jittered-exponential reconnect backoff.
    pub reconnect_base: Duration,
    /// Cap on a single reconnect backoff window.
    pub reconnect_cap: Duration,
}

impl Default for NetTuning {
    fn default() -> NetTuning {
        NetTuning {
            hb_interval: Duration::from_millis(50),
            hb_timeout: Duration::from_secs(5),
            transfer_deadline: Duration::from_secs(5),
            reconnect_base: Duration::from_millis(50),
            reconnect_cap: Duration::from_secs(2),
        }
    }
}

impl NetTuning {
    /// Reject zero/absurd values at the boundary. Returns `self` so
    /// call sites can write `cfg.net.validated()?`.
    pub fn validated(self) -> anyhow::Result<NetTuning> {
        anyhow::ensure!(
            self.hb_interval > Duration::ZERO && self.hb_interval <= Duration::from_secs(60),
            "heartbeat interval must be in (0s, 60s], got {:?}",
            self.hb_interval
        );
        anyhow::ensure!(
            self.hb_timeout >= self.hb_interval.saturating_mul(2),
            "heartbeat timeout {:?} must be at least twice the interval {:?}",
            self.hb_timeout,
            self.hb_interval
        );
        anyhow::ensure!(
            self.hb_timeout <= Duration::from_secs(600),
            "heartbeat timeout must be at most 600s, got {:?}",
            self.hb_timeout
        );
        anyhow::ensure!(
            self.transfer_deadline > Duration::ZERO
                && self.transfer_deadline <= Duration::from_secs(600),
            "transfer deadline must be in (0s, 600s], got {:?}",
            self.transfer_deadline
        );
        anyhow::ensure!(
            self.reconnect_base > Duration::ZERO,
            "reconnect backoff base must be positive"
        );
        anyhow::ensure!(
            self.reconnect_base <= self.reconnect_cap
                && self.reconnect_cap <= Duration::from_secs(60),
            "reconnect backoff cap must be in [base, 60s], got base {:?} cap {:?}",
            self.reconnect_base,
            self.reconnect_cap
        );
        Ok(self)
    }
}

// ---------------------------------------------------------------------------
// Control-plane connection abstraction (UDS or TCP)
// ---------------------------------------------------------------------------

enum CtrlStream {
    Uds(UnixStream),
    Tcp(TcpStream),
}

impl Read for CtrlStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            CtrlStream::Uds(s) => s.read(buf),
            CtrlStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for CtrlStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            CtrlStream::Uds(s) => s.write(buf),
            CtrlStream::Tcp(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            CtrlStream::Uds(s) => s.flush(),
            CtrlStream::Tcp(s) => s.flush(),
        }
    }
}

/// One control-plane connection: a UDS or TCP stream plus the frame
/// codec it speaks (plain on UDS, CRC-trailered on TCP). The
/// coordinator and the workers exchange the same frames either way; the
/// transport choice never leaks into the protocol.
pub struct Conn {
    stream: CtrlStream,
    codec: Codec,
}

impl Conn {
    pub fn uds(s: UnixStream) -> Conn {
        Conn { stream: CtrlStream::Uds(s), codec: Codec::Plain }
    }

    pub fn tcp(s: TcpStream) -> Conn {
        let _ = s.set_nodelay(true);
        Conn { stream: CtrlStream::Tcp(s), codec: Codec::Crc32 }
    }

    /// One dial attempt to a UDS control socket.
    pub fn connect_uds(path: &Path) -> io::Result<Conn> {
        Ok(Conn::uds(UnixStream::connect(path)?))
    }

    /// One dial attempt to a TCP control address (`host:port`).
    pub fn connect_tcp(addr: &str) -> io::Result<Conn> {
        Ok(Conn::tcp(TcpStream::connect(addr)?))
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    pub fn try_clone(&self) -> io::Result<Conn> {
        let stream = match &self.stream {
            CtrlStream::Uds(s) => CtrlStream::Uds(s.try_clone()?),
            CtrlStream::Tcp(s) => CtrlStream::Tcp(s.try_clone()?),
        };
        Ok(Conn { stream, codec: self.codec })
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match &self.stream {
            CtrlStream::Uds(s) => s.set_read_timeout(d),
            CtrlStream::Tcp(s) => s.set_read_timeout(d),
        }
    }

    pub fn set_write_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match &self.stream {
            CtrlStream::Uds(s) => s.set_write_timeout(d),
            CtrlStream::Tcp(s) => s.set_write_timeout(d),
        }
    }

    pub fn read_frame(&mut self) -> Result<(u8, Vec<u8>), TransportError> {
        self.codec.read(&mut self.stream)
    }

    pub fn write_frame(&mut self, kind: u8, payload: &[u8]) -> io::Result<()> {
        self.codec.write(&mut self.stream, kind, payload)
    }
}

/// The coordinator's control-plane listener: UDS on one host, TCP for
/// multi-host (bound before any worker spawns, so the first dial never
/// races the bind).
pub enum CtrlListener {
    Uds(UnixListener),
    Tcp(TcpListener),
}

impl CtrlListener {
    pub fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            CtrlListener::Uds(l) => l.set_nonblocking(nb),
            CtrlListener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    /// Accept one control connection with the listener's codec applied.
    pub fn accept(&self) -> io::Result<Conn> {
        match self {
            CtrlListener::Uds(l) => {
                let (s, _) = l.accept()?;
                Ok(Conn::uds(s))
            }
            CtrlListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                Ok(Conn::tcp(s))
            }
        }
    }

    /// The bound TCP address (for `--ctrl-addr` hand-off); `None` on
    /// UDS.
    pub fn tcp_addr(&self) -> Option<SocketAddr> {
        match self {
            CtrlListener::Uds(_) => None,
            CtrlListener::Tcp(l) => l.local_addr().ok(),
        }
    }
}

// ---------------------------------------------------------------------------
// Peer transport trait + UDS implementation
// ---------------------------------------------------------------------------

/// A live backend for cross-process owner fetches, installed on the
/// fabric with [`Fabric::set_transport`](super::Fabric::set_transport).
/// Learner ids are *global* (rank-major: learner `l` lives in process
/// `l / g`).
pub trait PeerTransport: Send + Sync {
    /// True when `learner`'s cache lives in this process (served by the
    /// ordinary in-process path, no socket round-trip).
    fn serves_local(&self, learner: usize) -> bool;

    /// Fetch `ids` from `owner`'s process. Per id: `Some((label, bytes))`
    /// on a hit, `None` when the owner no longer holds it (the caller
    /// repairs the claim and falls back to storage). An `Err` fails the
    /// whole group — the caller treats the owner as unreachable.
    fn fetch_from_owner(
        &self,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError>;

    /// Membership hook: stop dialing `rank` (its claims are being
    /// evicted); a queued fetch already in flight may still fail.
    fn mark_dead(&self, rank: usize);

    /// Membership hook: `rank` rejoined at an epoch boundary.
    fn mark_alive(&self, rank: usize);
}

struct PeerSlot {
    conn: Mutex<Option<UnixStream>>,
    state: PeerState,
}

/// UDS client: one lazily-dialed, cached connection per peer rank, with
/// a [`PeerState`] health machine gating the dials.
///
/// Connections are re-dialed once per fetch if the cached stream fails
/// *before any response byte is read* (a stale socket from a peer
/// restart). Once response bytes have been consumed the fetch is never
/// retried: a short read means the peer died mid-serve, and retrying
/// could double-count a transfer that the peer already completed.
pub struct UdsPeers {
    my_rank: usize,
    /// Learners per rank (global learner `l` ⇒ rank `l / g`).
    g: usize,
    paths: Vec<PathBuf>,
    slots: Vec<PeerSlot>,
    backoff_base: Duration,
    backoff_cap: Duration,
}

impl UdsPeers {
    pub fn new(my_rank: usize, learners_per_rank: usize, paths: Vec<PathBuf>) -> UdsPeers {
        let tuning = NetTuning::default();
        let slots = (0..paths.len())
            .map(|_| PeerSlot { conn: Mutex::new(None), state: PeerState::new() })
            .collect();
        UdsPeers {
            my_rank,
            g: learners_per_rank.max(1),
            paths,
            slots,
            backoff_base: tuning.reconnect_base,
            backoff_cap: tuning.reconnect_cap,
        }
    }

    /// Override the reconnect backoff window (from [`NetTuning`]).
    pub fn with_backoff(mut self, base: Duration, cap: Duration) -> UdsPeers {
        self.backoff_base = base;
        self.backoff_cap = cap.max(base);
        self
    }

    /// The socket path a given rank's peer server binds.
    pub fn peer_path(rendezvous: &Path, rank: usize) -> PathBuf {
        rendezvous.join(format!("peer-{rank}.sock"))
    }

    /// Health of the link to `rank` (observability + tests).
    pub fn peer_health(&self, rank: usize) -> Option<PeerHealth> {
        self.slots.get(rank).map(|s| s.state.health())
    }

    fn exchange(
        &self,
        stream: &mut UnixStream,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
        let rank = owner / self.g;
        stream
            .set_read_timeout(deadline)
            .and_then(|_| stream.set_write_timeout(deadline))
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let mut req = Wire::new();
        req.u32(owner as u32).vec_u32(ids);
        write_frame(stream, PFETCH, &req.take())
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let (kind, payload) =
            read_frame(stream).map_err(|e| e.classify(rank, deadline))?;
        decode_samples(kind, &payload, ids.len())
    }

    /// Record `err`'s health consequence for `rank`.
    fn note_failure(&self, rank: usize, err: &TransportError) {
        let Some(slot) = self.slots.get(rank) else { return };
        match err {
            TransportError::Stall(_) => slot.state.note_stall(),
            _ => {
                let salt = ((self.my_rank as u64) << 32) | rank as u64;
                slot.state
                    .note_disconnect(salt, self.backoff_base, self.backoff_cap);
            }
        }
    }
}

/// Decode a PSAMP (or PSAMP_SHM) response into per-id hits. Shared by
/// the UDS and TCP clients.
pub(crate) fn decode_samples(
    kind: u8,
    payload: &[u8],
    expect: usize,
) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
    if kind != PSAMP {
        return Err(TransportError::Malformed("unexpected peer frame kind"));
    }
    let mut r = WireReader::new(payload);
    let n = r.u32()? as usize;
    if n != expect {
        return Err(TransportError::Malformed("sample count mismatch"));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if r.u8()? == 0 {
            out.push(None);
            continue;
        }
        let label = r.u16()?;
        let len = r.u32()? as usize;
        if len > MAX_FRAME {
            return Err(TransportError::Malformed("sample over-long"));
        }
        out.push(Some((label, r.take(len)?.to_vec())));
    }
    Ok(out)
}

impl PeerTransport for UdsPeers {
    fn serves_local(&self, learner: usize) -> bool {
        learner / self.g == self.my_rank
    }

    fn fetch_from_owner(
        &self,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
        let rank = owner / self.g;
        let slot = self
            .slots
            .get(rank)
            .ok_or(TransportError::Malformed("owner rank out of range"))?;
        if slot.state.is_dead() || slot.state.in_backoff() {
            // Dead (membership) or inside the reconnect backoff window:
            // refuse fail-fast so the caller demotes to storage fallback
            // instead of hammering a gone/recovering peer.
            return Err(TransportError::PeerClosed { peer: rank });
        }
        let mut guard = slot.conn.lock().unwrap();
        let had_cached = guard.is_some();
        if guard.is_none() {
            match UnixStream::connect(&self.paths[rank]) {
                Ok(s) => *guard = Some(s),
                Err(e) => {
                    let err = TransportError::from_io(e, rank, deadline);
                    self.note_failure(rank, &err);
                    return Err(err);
                }
            }
        }
        let mut stream = guard.take().unwrap();
        match self.exchange(&mut stream, owner, ids, deadline) {
            Ok(out) => {
                slot.state.note_success();
                *guard = Some(stream);
                Ok(out)
            }
            Err(TransportError::PeerClosed { .. }) if had_cached => {
                // The cached stream was stale (peer restarted since the
                // last fetch). Dial fresh and retry exactly once: the
                // request is idempotent and no response byte was
                // accepted from the dead stream, so nothing can be
                // double-counted.
                let fresh = UnixStream::connect(&self.paths[rank])
                    .map_err(|e| TransportError::from_io(e, rank, deadline));
                let out = fresh.and_then(|mut fresh| {
                    self.exchange(&mut fresh, owner, ids, deadline)
                        .map(|out| (out, fresh))
                });
                match out {
                    Ok((out, fresh)) => {
                        slot.state.note_success();
                        *guard = Some(fresh);
                        Ok(out)
                    }
                    Err(e) => {
                        self.note_failure(rank, &e);
                        Err(e)
                    }
                }
            }
            Err(e) => {
                self.note_failure(rank, &e);
                Err(e)
            }
        }
    }

    fn mark_dead(&self, rank: usize) {
        if let Some(slot) = self.slots.get(rank) {
            slot.state.mark_dead();
            *slot.conn.lock().unwrap() = None;
        }
    }

    fn mark_alive(&self, rank: usize) {
        if let Some(slot) = self.slots.get(rank) {
            // Revival must clear the health state *and* drop the stale
            // cached connection: the rejoined peer is a new process, and
            // a leftover stream (or leftover backoff) would refuse it
            // forever.
            slot.state.mark_alive();
            *slot.conn.lock().unwrap() = None;
        }
    }
}

// ---------------------------------------------------------------------------
// Serve loop (shared by the UDS and TCP servers)
// ---------------------------------------------------------------------------

/// A stream the serve loop can read/write with kernel-level timeouts —
/// the least common denominator of [`UnixStream`] and [`TcpStream`].
pub(crate) trait NetStream: Read + Write {
    fn set_read_deadline(&self, d: Option<Duration>) -> io::Result<()>;
    fn set_write_deadline(&self, d: Option<Duration>) -> io::Result<()>;
}

impl NetStream for UnixStream {
    fn set_read_deadline(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }
    fn set_write_deadline(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_write_timeout(d)
    }
}

impl NetStream for TcpStream {
    fn set_read_deadline(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }
    fn set_write_deadline(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_write_timeout(d)
    }
}

/// Serve [`PFETCH`] requests on one connection until EOF, protocol
/// error, or shutdown. `chaos` (TCP only) may tear, corrupt, or delay
/// the reply — the client's typed-error handling is exactly what the
/// injector exercises.
pub(crate) fn serve_stream<S: NetStream>(
    conn: &mut S,
    caches: &HashMap<usize, Arc<CacheStack>>,
    stop: &AtomicBool,
    codec: Codec,
    chaos: Option<&NetChaos>,
) {
    // Bounded reads so the handler re-checks the shutdown flag instead
    // of parking forever on an idle client.
    let _ = conn.set_read_deadline(Some(Duration::from_millis(100)));
    while !stop.load(Ordering::Acquire) {
        let (kind, payload) = match codec.read(conn) {
            Ok(f) => f,
            Err(TransportError::Io(e))
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                // Idle poll tick (no frame started): keep waiting. A
                // timeout *mid-frame* is a ShortRead and falls through
                // to the disconnect arm — continuing there would desync
                // the stream.
                continue;
            }
            Err(_) => return, // EOF, torn frame, or protocol error.
        };
        if kind != PFETCH {
            return;
        }
        let mut r = WireReader::new(&payload);
        let (learner, ids) = match (|| {
            let learner = r.u32()? as usize;
            let ids = r.vec_u32()?;
            Ok::<_, TransportError>((learner, ids))
        })() {
            Ok(v) => v,
            Err(_) => return,
        };
        let mut resp = Wire::new();
        resp.u32(ids.len() as u32);
        let stack = caches.get(&learner);
        for id in &ids {
            match stack.and_then(|s| s.get(*id)) {
                Some(sample) => {
                    let bytes = sample.bytes.as_slice();
                    resp.u8(1).u16(sample.label).u32(bytes.len() as u32).bytes(bytes);
                }
                None => {
                    resp.u8(0);
                }
            }
        }
        let _ = conn.set_write_deadline(Some(Duration::from_secs(30)));
        let payload = resp.take();
        if let Some(c) = chaos {
            if c.next_delay() {
                thread::sleep(Duration::from_millis(c.delay_ms()));
            }
            if c.next_tear() {
                // Encode the full frame but write only a prefix, then
                // hang up: the client must see a typed ShortRead (or
                // Corrupt), never a half-parsed success.
                let mut buf = Vec::new();
                let _ = codec.write(&mut buf, PSAMP, &payload);
                let cut = (buf.len() / 2).max(1);
                let _ = conn.write_all(&buf[..cut]);
                let _ = conn.flush();
                return;
            }
            if c.next_flip() {
                // Flip one bit past the length header: the frame still
                // parses to the CRC check, which must reject it.
                let mut buf = Vec::new();
                let _ = codec.write(&mut buf, PSAMP, &payload);
                if let Some(bit) = c.flip_bit(buf.len()) {
                    buf[bit / 8] ^= 1 << (bit % 8);
                }
                if conn.write_all(&buf).is_err() || conn.flush().is_err() {
                    return;
                }
                continue;
            }
        }
        if codec.write(conn, PSAMP, &payload).is_err() {
            return;
        }
    }
}

/// UDS server: serves this process's learner caches to its peers.
///
/// One accept thread, one handler thread per peer connection. Requests
/// are [`PFETCH`] frames (target learner + sample ids); the reply is one
/// [`PSAMP`] frame with per-id hit flags, so a short read on the client
/// is always distinguishable from a miss.
pub struct PeerServer {
    path: PathBuf,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl PeerServer {
    /// Bind `path` (unlinking any stale socket first) and serve
    /// `caches`, a map from *global* learner id to that learner's stack.
    pub fn start(
        path: PathBuf,
        caches: HashMap<usize, Arc<CacheStack>>,
    ) -> io::Result<PeerServer> {
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = shutdown.clone();
        let caches = Arc::new(caches);
        let accept_thread = thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((mut conn, _)) => {
                        let caches = caches.clone();
                        let stop = stop.clone();
                        thread::spawn(move || {
                            serve_stream(&mut conn, &caches, &stop, Codec::Plain, None)
                        });
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(PeerServer {
            path,
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for PeerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Shared-memory payload ring (feature `shm-ring`): the server bump-
/// allocates payload bytes into an mmap-shared file; clients map the
/// same file read-only and build zero-copy `SampleBytes` views. Kept
/// deliberately simple — a full ring would recycle; this segment serves
/// an epoch's working set and falls back to inline frames when full.
#[cfg(feature = "shm-ring")]
pub mod shm {
    use crate::storage::SampleBytes;
    use std::fs::{File, OpenOptions};
    use std::io;
    use std::path::Path;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    pub struct ShmWriter {
        file: File,
        capacity: u64,
        cursor: AtomicU64,
    }

    impl ShmWriter {
        pub fn create(path: &Path, capacity: u64) -> io::Result<ShmWriter> {
            let file = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(path)?;
            file.set_len(capacity)?;
            Ok(ShmWriter { file, capacity, cursor: AtomicU64::new(0) })
        }

        /// Reserve + write; returns the segment offset, or `None` when
        /// the ring is full (caller falls back to an inline frame).
        pub fn push(&self, bytes: &[u8]) -> Option<u64> {
            use std::os::unix::fs::FileExt;
            let len = bytes.len() as u64;
            let off = self.cursor.fetch_add(len, Ordering::Relaxed);
            if off + len > self.capacity {
                return None;
            }
            self.file.write_all_at(bytes, off).ok()?;
            Some(off)
        }
    }

    pub struct ShmReader {
        map: Arc<crate::storage::bytes::Mmap>,
    }

    impl ShmReader {
        pub fn open(path: &Path) -> io::Result<ShmReader> {
            let file = File::open(path)?;
            let map = crate::storage::bytes::Mmap::map_shared(&file)?;
            Ok(ShmReader { map: Arc::new(map) })
        }

        /// Zero-copy view into the ring.
        pub fn view(&self, off: u64, len: u32) -> SampleBytes {
            SampleBytes::from_map(self.map.clone(), off as usize, len as usize)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Policy;
    use crate::storage::Sample;

    fn tmp_sock(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "dlio-tsock-{tag}-{}-{:?}.sock",
            std::process::id(),
            thread::current().id()
        ))
    }

    fn stack_with(ids: &[(u32, u16, Vec<u8>)]) -> Arc<CacheStack> {
        let stack = Arc::new(CacheStack::mem_only(u64::MAX, Policy::InsertOnly));
        for (id, label, bytes) in ids {
            stack.insert(Arc::new(Sample {
                id: *id,
                bytes: bytes.clone().into(),
                label: *label,
            }));
        }
        stack
    }

    #[test]
    fn frame_roundtrip_and_wire_codec() {
        let mut buf = Vec::new();
        let mut w = Wire::new();
        w.u8(7).u16(300).u32(1 << 20).u64(1 << 40).f32(0.5).vec_u32(&[1, 2, 3]);
        write_frame(&mut buf, PFETCH, &w.take()).unwrap();
        let (kind, payload) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(kind, PFETCH);
        let mut r = WireReader::new(&payload);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 1 << 20);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 0.5);
        assert_eq!(r.vec_u32().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn oversized_and_truncated_frames_are_typed_errors() {
        // Header announcing more than MAX_FRAME must not allocate.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut &huge[..]).unwrap_err();
        assert!(
            matches!(err, TransportError::FrameTooLarge { declared } if declared == u32::MAX as u64),
            "{err}"
        );
        // A zero length is malformed, not a zero-byte allocation.
        let zero = 0u32.to_le_bytes();
        let err = read_frame(&mut &zero[..]).unwrap_err();
        assert!(matches!(err, TransportError::Malformed(_)), "{err}");
        // Truncated payload is a typed ShortRead, not a panic.
        let mut buf = Vec::new();
        write_frame(&mut buf, PSAMP, &[1, 2, 3, 4]).unwrap();
        buf.truncate(buf.len() - 2);
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(
            matches!(
                err,
                TransportError::ShortRead { needed: 5, got: 3, timed_out: false }
            ),
            "{err}"
        );
        // EOF at a clean frame boundary stays distinguishable.
        let err = read_frame(&mut &[][..]).unwrap_err();
        assert!(
            matches!(&err, TransportError::Io(e) if e.kind() == io::ErrorKind::UnexpectedEof),
            "{err}"
        );
        // WireReader over-reads are Malformed errors.
        let mut r = WireReader::new(&[1, 2]);
        assert!(matches!(r.u32(), Err(TransportError::Malformed(_))));
    }

    #[test]
    fn crc32_matches_the_iso_hdlc_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc_frames_roundtrip_and_reject_corruption() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let mut buf = Vec::new();
        write_frame_crc(&mut buf, PSAMP, &payload).unwrap();
        assert_eq!(buf.len(), 4 + 1 + payload.len() + 4);
        let (kind, got) = read_frame_crc(&mut &buf[..]).unwrap();
        assert_eq!((kind, got.as_slice()), (PSAMP, payload.as_slice()));
        // Any single corrupted body byte must surface as Corrupt.
        for at in [4usize, 5, 100, buf.len() - 5] {
            let mut bad = buf.clone();
            bad[at] ^= 0x40;
            let err = read_frame_crc(&mut &bad[..]).unwrap_err();
            assert!(matches!(err, TransportError::Corrupt { .. }), "byte {at}: {err}");
        }
        // A corrupted trailer too.
        let mut bad = buf.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        let err = read_frame_crc(&mut &bad[..]).unwrap_err();
        assert!(matches!(err, TransportError::Corrupt { .. }), "{err}");
        // Truncation mid-trailer is a ShortRead, not a bogus CRC pass.
        let mut short = buf.clone();
        short.truncate(buf.len() - 2);
        let err = read_frame_crc(&mut &short[..]).unwrap_err();
        assert!(matches!(err, TransportError::ShortRead { .. }), "{err}");
    }

    /// A reader that delivers one byte at a time and injects EINTR
    /// before every byte — the satellite's partial-read/EINTR loop.
    struct DribbleReader<'a> {
        data: &'a [u8],
        pos: usize,
        interrupt_next: bool,
    }

    impl Read for DribbleReader<'_> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.interrupt_next {
                self.interrupt_next = false;
                return Err(io::Error::new(io::ErrorKind::Interrupted, "eintr"));
            }
            self.interrupt_next = true;
            if self.pos >= self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn partial_reads_and_eintr_are_retried_to_completion() {
        let mut buf = Vec::new();
        write_frame_crc(&mut buf, PFETCH, b"dribble").unwrap();
        let mut r = DribbleReader { data: &buf, pos: 0, interrupt_next: true };
        let (kind, payload) = read_frame_crc(&mut r).unwrap();
        assert_eq!((kind, payload.as_slice()), (PFETCH, b"dribble".as_slice()));
        // Same for the plain codec.
        let mut plain = Vec::new();
        write_frame(&mut plain, PSAMP, b"xy").unwrap();
        let mut r = DribbleReader { data: &plain, pos: 0, interrupt_next: true };
        assert_eq!(read_frame(&mut r).unwrap(), (PSAMP, b"xy".to_vec()));
    }

    #[test]
    fn peer_state_machine_transitions() {
        let s = PeerState::new();
        assert_eq!(s.health(), PeerHealth::Connected);
        assert!(!s.in_backoff());
        s.note_stall();
        assert_eq!(s.health(), PeerHealth::Degraded);
        s.note_success();
        assert_eq!(s.health(), PeerHealth::Connected);
        // A disconnect opens a backoff window.
        s.note_disconnect(7, Duration::from_secs(1), Duration::from_secs(2));
        assert_eq!(s.health(), PeerHealth::Reconnecting);
        assert_eq!(s.failures(), 1);
        assert!(s.in_backoff());
        // Membership death is terminal against further observations...
        s.mark_dead();
        s.note_success();
        s.note_stall();
        assert_eq!(s.health(), PeerHealth::Dead);
        assert!(s.is_dead());
        // ...until an epoch-boundary revival clears everything.
        s.mark_alive();
        assert_eq!(s.health(), PeerHealth::Connected);
        assert_eq!(s.failures(), 0);
        assert!(!s.in_backoff());
    }

    #[test]
    fn backoff_window_expires() {
        let s = PeerState::new();
        s.note_disconnect(1, Duration::from_micros(50), Duration::from_millis(1));
        assert_eq!(s.health(), PeerHealth::Reconnecting);
        thread::sleep(Duration::from_millis(5));
        assert!(!s.in_backoff(), "a 1ms-capped window must expire");
    }

    #[test]
    fn net_tuning_rejects_absurd_values() {
        assert!(NetTuning::default().validated().is_ok());
        let zero_hb = NetTuning { hb_interval: Duration::ZERO, ..NetTuning::default() };
        assert!(zero_hb.validated().is_err());
        let tight_timeout = NetTuning {
            hb_interval: Duration::from_secs(3),
            hb_timeout: Duration::from_secs(4),
            ..NetTuning::default()
        };
        assert!(tight_timeout.validated().is_err());
        let zero_deadline =
            NetTuning { transfer_deadline: Duration::ZERO, ..NetTuning::default() };
        assert!(zero_deadline.validated().is_err());
        let inverted = NetTuning {
            reconnect_base: Duration::from_secs(5),
            reconnect_cap: Duration::from_secs(1),
            ..NetTuning::default()
        };
        assert!(inverted.validated().is_err());
        let absurd_cap = NetTuning {
            reconnect_cap: Duration::from_secs(3600),
            ..NetTuning::default()
        };
        assert!(absurd_cap.validated().is_err());
    }

    #[test]
    fn uds_serves_hits_and_misses() {
        let path = tmp_sock("serve");
        let mut caches = HashMap::new();
        caches.insert(3usize, stack_with(&[(10, 4, vec![1, 2, 3]), (11, 5, vec![9])]));
        let _server = PeerServer::start(path.clone(), caches).unwrap();
        let peers = UdsPeers::new(0, 2, vec![path.clone(), path.clone()]);
        // Owner 3 lives on rank 1 (g = 2).
        assert!(!peers.serves_local(3));
        assert!(peers.serves_local(1));
        let out = peers
            .fetch_from_owner(3, &[10, 99, 11], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((4, vec![1, 2, 3])));
        assert_eq!(out[1], None);
        assert_eq!(out[2], Some((5, vec![9])));
        assert_eq!(peers.peer_health(1), Some(PeerHealth::Connected));
    }

    /// Satellite: EOF racing a completed transfer. The peer writes the
    /// complete response and *immediately* closes the socket. The first
    /// fetch must succeed exactly once (the samples were delivered); the
    /// next fetch on the now-dead cached connection must surface peer
    /// death — never a duplicated success.
    #[test]
    fn eof_after_complete_response_does_not_double_count() {
        let path = tmp_sock("eofrace");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let server = thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let (kind, payload) = read_frame(&mut conn).unwrap();
            assert_eq!(kind, PFETCH);
            let mut r = WireReader::new(&payload);
            let _learner = r.u32().unwrap();
            let ids = r.vec_u32().unwrap();
            let mut resp = Wire::new();
            resp.u32(ids.len() as u32);
            for _ in &ids {
                resp.u8(1).u16(1).u32(2).bytes(&[0xAB, 0xCD]);
            }
            write_frame(&mut conn, PSAMP, &resp.take()).unwrap();
            // Close right behind the response: EOF races the client read.
            drop(conn);
            // Listener drops here: no further connection is possible.
        });
        let peers = UdsPeers::new(1, 1, vec![path.clone(), path.clone()]);
        let out = peers
            .fetch_from_owner(0, &[5, 6], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|s| s == &Some((1, vec![0xAB, 0xCD]))));
        server.join().unwrap();
        let _ = std::fs::remove_file(&path);
        // The cached connection is dead and the listener is gone: the
        // retry dial fails too, so this is PeerClosed — the transfer is
        // not silently re-served or double-counted.
        let err = peers
            .fetch_from_owner(0, &[5], Some(Duration::from_secs(1)))
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 0 }), "{err}");
        assert_eq!(peers.peer_health(0), Some(PeerHealth::Reconnecting));
    }

    /// Satellite: a peer that died before ever serving (freeze-then-die
    /// at the transport level) surfaces as PeerClosed, mapped from the
    /// failed connect.
    #[test]
    fn connect_to_dead_peer_is_peer_closed() {
        let path = tmp_sock("deadpeer");
        let _ = std::fs::remove_file(&path);
        let peers = UdsPeers::new(0, 1, vec![tmp_sock("self"), path]);
        let err = peers
            .fetch_from_owner(1, &[0], Some(Duration::from_millis(100)))
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }), "{err}");
        // And once marked dead, the fetch short-circuits without dialing.
        peers.mark_dead(1);
        let err = peers.fetch_from_owner(1, &[0], None).unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }));
        peers.mark_alive(1);
    }

    /// Satellite (revival path): a peer that died, accumulated failures,
    /// and was marked dead must — after the PR 7 epoch-boundary rejoin
    /// calls `mark_alive` — be served by a *fresh* dial, not refused
    /// because of its previous incarnation's dead mark, backoff window,
    /// or stale cached connection.
    #[test]
    fn revived_peer_is_redialed_fresh_after_mark_alive() {
        let path = tmp_sock("revive");
        let mut caches = HashMap::new();
        caches.insert(1usize, stack_with(&[(7, 2, vec![0x11])]));
        let mut server = PeerServer::start(path.clone(), caches).unwrap();
        let peers = UdsPeers::new(0, 1, vec![tmp_sock("self2"), path.clone()])
            .with_backoff(Duration::from_secs(10), Duration::from_secs(10));
        // Healthy fetch caches a connection.
        let out = peers
            .fetch_from_owner(1, &[7], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((2, vec![0x11])));
        // Peer dies: the next fetch fails and opens a (huge) backoff
        // window, then membership marks it dead.
        server.stop();
        let _ = peers.fetch_from_owner(1, &[7], Some(Duration::from_millis(200)));
        peers.mark_dead(1);
        assert_eq!(peers.peer_health(1), Some(PeerHealth::Dead));
        let err = peers.fetch_from_owner(1, &[7], None).unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }));
        // Peer restarts (new process, same path) with different bytes
        // and rejoins at the epoch boundary.
        let mut caches = HashMap::new();
        caches.insert(1usize, stack_with(&[(7, 3, vec![0x22, 0x33])]));
        let _server2 = PeerServer::start(path.clone(), caches).unwrap();
        peers.mark_alive(1);
        assert_eq!(peers.peer_health(1), Some(PeerHealth::Connected));
        // The fetch must succeed immediately — no leftover dead mark, no
        // leftover 10s backoff, no stale socket — and must return the
        // *new* incarnation's bytes.
        let out = peers
            .fetch_from_owner(1, &[7], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((3, vec![0x22, 0x33])));
    }

    #[test]
    fn read_deadline_maps_to_transfer_stall() {
        let path = tmp_sock("stall");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        // A server that accepts and then never replies.
        let silent = thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            thread::sleep(Duration::from_millis(400));
            drop(conn);
        });
        let peers = UdsPeers::new(1, 1, vec![path.clone(), path.clone()]);
        let err = peers
            .fetch_from_owner(0, &[1], Some(Duration::from_millis(50)))
            .unwrap_err();
        match err {
            TransportError::Stall(s) => {
                assert_eq!(s.kind, StallKind::Transfer);
                let msg = s.to_string();
                assert!(msg.contains("transfer wait exceeded its deadline"), "{msg}");
            }
            other => panic!("expected transfer stall, got {other}"),
        }
        // A deadline miss degrades the link but does not open a backoff
        // window: the peer is slow, not gone.
        assert_eq!(peers.peer_health(0), Some(PeerHealth::Degraded));
        silent.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ctrl_conn_speaks_both_transports() {
        // TCP loopback with the CRC codec.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let ctrl = CtrlListener::Tcp(listener);
        assert_eq!(ctrl.tcp_addr(), Some(addr));
        let client = thread::spawn(move || {
            let mut conn = Conn::connect_tcp(&addr.to_string()).unwrap();
            conn.write_frame(9, b"hb").unwrap();
            let (kind, payload) = conn.read_frame().unwrap();
            assert_eq!((kind, payload.as_slice()), (2u8, b"welcome".as_slice()));
        });
        let mut server_conn = ctrl.accept().unwrap();
        assert_eq!(server_conn.codec(), Codec::Crc32);
        let (kind, payload) = server_conn.read_frame().unwrap();
        assert_eq!((kind, payload.as_slice()), (9u8, b"hb".as_slice()));
        server_conn.write_frame(2, b"welcome").unwrap();
        client.join().unwrap();
        // UDS with the plain codec.
        let path = tmp_sock("ctrl");
        let _ = std::fs::remove_file(&path);
        let ctrl = CtrlListener::Uds(UnixListener::bind(&path).unwrap());
        assert!(ctrl.tcp_addr().is_none());
        let cpath = path.clone();
        let client = thread::spawn(move || {
            let mut conn = Conn::connect_uds(&cpath).unwrap();
            conn.write_frame(1, b"hello").unwrap();
        });
        let mut server_conn = ctrl.accept().unwrap();
        assert_eq!(server_conn.codec(), Codec::Plain);
        let (kind, payload) = server_conn.read_frame().unwrap();
        assert_eq!((kind, payload.as_slice()), (1u8, b"hello".as_slice()));
        client.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
