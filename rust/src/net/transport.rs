//! Real-transport backends for owner-to-owner sample transfers
//! (DESIGN.md §13).
//!
//! The in-process [`Fabric`](super::Fabric) stays the fast deterministic
//! tier: virtual-time link clocks, no syscalls, bit-identical accounting.
//! This module adds the live tier used by the supervised multi-process
//! mode: each learner-group process serves its cache over a Unix-domain
//! socket with a length-prefixed frame codec, and the fetch path routes
//! any owner group whose owner lives in *another* process through a
//! [`PeerTransport`] installed on the fabric. Deadlines map onto the
//! existing [`fault::Deadlines`](crate::fault::Deadlines) budgets: a
//! read/write that exceeds its budget surfaces as a
//! [`StallError`](crate::fault::StallError) with [`StallKind::Transfer`],
//! indistinguishable (by design) from an in-process transfer stall, so
//! the PR 7 recovery path — evict claims, fall back to storage, mark the
//! peer dead — handles both tiers with one code path.
//!
//! ## Frame format
//!
//! Every message on every socket (peer and control) is one frame:
//!
//! ```text
//! [len: u32 LE] [kind: u8] [payload: len-1 bytes]
//! ```
//!
//! `len` counts the kind byte plus the payload and is capped at
//! [`MAX_FRAME`]; a frame that announces more is malformed, not a reason
//! to allocate. Multi-byte integers inside payloads are little-endian
//! (see [`Wire`]/[`WireReader`]).
//!
//! ## Shared-memory ring (feature `shm-ring`)
//!
//! Behind the `shm-ring` feature the server can place sample payloads in
//! a preallocated mmap-shared segment and answer with (offset, len)
//! descriptors instead of inline bytes; the client maps the same file
//! and constructs zero-copy [`SampleBytes`](crate::storage::SampleBytes)
//! views, reusing the PR 5 spill-segment machinery. When the ring is
//! full the server transparently falls back to inline frames, so the
//! ring is an optimization, never a correctness dependency.

use crate::cache::CacheStack;
use crate::fault::{StallError, StallKind};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// Hard cap on a single frame (header-declared), peer and control alike.
pub const MAX_FRAME: usize = 64 << 20;

/// Peer protocol frame kinds (control-plane kinds live in
/// `coordinator::service`).
pub const PFETCH: u8 = 20;
pub const PSAMP: u8 = 21;
#[cfg(feature = "shm-ring")]
pub const PSAMP_SHM: u8 = 22;

/// Which transport backs cross-process owner fetches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Threads in one process over the virtual fabric (no transport
    /// installed) — the deterministic tier.
    InProc,
    /// Unix-domain sockets with inline frame payloads.
    Uds,
    /// UDS control frames + shared-memory payload ring (`shm-ring`
    /// feature; falls back to inline frames when the ring is full).
    #[cfg(feature = "shm-ring")]
    Shm,
}

impl TransportKind {
    /// Parse a `--transport` CLI value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inproc" | "threads" => Some(TransportKind::InProc),
            "uds" => Some(TransportKind::Uds),
            #[cfg(feature = "shm-ring")]
            "shm" => Some(TransportKind::Shm),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Uds => "uds",
            #[cfg(feature = "shm-ring")]
            TransportKind::Shm => "shm",
        }
    }
}

/// Transport-layer failure, already classified for the recovery path.
#[derive(Debug)]
pub enum TransportError {
    /// A read/write/connect exceeded its deadline budget. Carries the
    /// same [`StallError`] the in-process fabric raises, so stall
    /// accounting and exit-code mapping see one taxonomy.
    Stall(StallError),
    /// The peer's socket reached EOF (or refused the connection): the
    /// process died or was killed. Routed into the membership path.
    PeerClosed { peer: usize },
    /// Any other socket-level error.
    Io(io::Error),
    /// The peer spoke, but not the protocol.
    Malformed(&'static str),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Stall(s) => write!(f, "{s}"),
            TransportError::PeerClosed { peer } => {
                write!(f, "peer process {peer} closed the connection")
            }
            TransportError::Io(e) => write!(f, "transport i/o error: {e}"),
            TransportError::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl TransportError {
    /// Classify an `io::Error` from a deadlined socket operation on the
    /// link to `peer`: timeouts become transfer stalls charged at the
    /// full budget, EOF becomes peer death.
    fn from_io(e: io::Error, peer: usize, deadline: Option<Duration>) -> TransportError {
        match e.kind() {
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                let budget = deadline.unwrap_or(Duration::ZERO);
                TransportError::Stall(StallError {
                    kind: StallKind::Transfer,
                    waited: budget,
                    deadline: budget,
                })
            }
            io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionRefused
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::NotFound => TransportError::PeerClosed { peer },
            _ => TransportError::Io(e),
        }
    }
}

/// Write one `[len][kind][payload]` frame.
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
    let len = payload.len() + 1;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidInput, "frame too large"));
    }
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame; EOF at a frame boundary surfaces as
/// `ErrorKind::UnexpectedEof` (the caller decides whether that boundary
/// was clean).
pub fn read_frame(r: &mut impl Read) -> io::Result<(u8, Vec<u8>)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    let mut payload = vec![0u8; len - 1];
    r.read_exact(&mut payload)?;
    Ok((kind[0], payload))
}

/// Little-endian payload writer.
#[derive(Default)]
pub struct Wire(Vec<u8>);

impl Wire {
    pub fn new() -> Wire {
        Wire(Vec::new())
    }
    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.0.push(v);
        self
    }
    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.0.extend_from_slice(v);
        self
    }
    /// Length-prefixed `u32` vector.
    pub fn vec_u32(&mut self, v: &[u32]) -> &mut Self {
        self.u32(v.len() as u32);
        for x in v {
            self.u32(*x);
        }
        self
    }
    /// Length-prefixed `f32` vector.
    pub fn vec_f32(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
        self
    }
    pub fn take(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.0)
    }
}

/// Bounds-checked little-endian payload reader; every decoder error is a
/// typed [`TransportError::Malformed`], never a panic.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn need(&mut self, n: usize) -> Result<&'a [u8], TransportError> {
        if self.buf.len() - self.pos < n {
            return Err(TransportError::Malformed("payload truncated"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, TransportError> {
        Ok(self.need(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16, TransportError> {
        Ok(u16::from_le_bytes(self.need(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32, TransportError> {
        Ok(u32::from_le_bytes(self.need(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64, TransportError> {
        Ok(u64::from_le_bytes(self.need(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32, TransportError> {
        Ok(f32::from_le_bytes(self.need(4)?.try_into().unwrap()))
    }
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], TransportError> {
        self.need(n)
    }
    pub fn vec_u32(&mut self) -> Result<Vec<u32>, TransportError> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 4 {
            return Err(TransportError::Malformed("u32 vector over-long"));
        }
        (0..n).map(|_| self.u32()).collect()
    }
    pub fn vec_f32(&mut self) -> Result<Vec<f32>, TransportError> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 4 {
            return Err(TransportError::Malformed("f32 vector over-long"));
        }
        (0..n).map(|_| self.f32()).collect()
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// A live backend for cross-process owner fetches, installed on the
/// fabric with [`Fabric::set_transport`](super::Fabric::set_transport).
/// Learner ids are *global* (rank-major: learner `l` lives in process
/// `l / g`).
pub trait PeerTransport: Send + Sync {
    /// True when `learner`'s cache lives in this process (served by the
    /// ordinary in-process path, no socket round-trip).
    fn serves_local(&self, learner: usize) -> bool;

    /// Fetch `ids` from `owner`'s process. Per id: `Some((label, bytes))`
    /// on a hit, `None` when the owner no longer holds it (the caller
    /// repairs the claim and falls back to storage). An `Err` fails the
    /// whole group — the caller treats the owner as unreachable.
    fn fetch_from_owner(
        &self,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError>;

    /// Membership hook: stop dialing `rank` (its claims are being
    /// evicted); a queued fetch already in flight may still fail.
    fn mark_dead(&self, rank: usize);

    /// Membership hook: `rank` rejoined at an epoch boundary.
    fn mark_alive(&self, rank: usize);
}

struct PeerSlot {
    conn: Mutex<Option<UnixStream>>,
    dead: AtomicBool,
}

/// UDS client: one lazily-dialed, cached connection per peer rank.
///
/// Connections are re-dialed once per fetch if the cached stream fails
/// *before any response byte is read* (a stale socket from a peer
/// restart). Once response bytes have been consumed the fetch is never
/// retried: a short read means the peer died mid-serve, and retrying
/// could double-count a transfer that the peer already completed.
pub struct UdsPeers {
    my_rank: usize,
    /// Learners per rank (global learner `l` ⇒ rank `l / g`).
    g: usize,
    paths: Vec<PathBuf>,
    slots: Vec<PeerSlot>,
}

impl UdsPeers {
    pub fn new(my_rank: usize, learners_per_rank: usize, paths: Vec<PathBuf>) -> UdsPeers {
        let slots = (0..paths.len())
            .map(|_| PeerSlot {
                conn: Mutex::new(None),
                dead: AtomicBool::new(false),
            })
            .collect();
        UdsPeers {
            my_rank,
            g: learners_per_rank.max(1),
            paths,
            slots,
        }
    }

    /// The socket path a given rank's peer server binds.
    pub fn peer_path(rendezvous: &Path, rank: usize) -> PathBuf {
        rendezvous.join(format!("peer-{rank}.sock"))
    }

    fn exchange(
        &self,
        stream: &mut UnixStream,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
        let rank = owner / self.g;
        stream
            .set_read_timeout(deadline)
            .and_then(|_| stream.set_write_timeout(deadline))
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let mut req = Wire::new();
        req.u32(owner as u32).vec_u32(ids);
        write_frame(stream, PFETCH, &req.take())
            .map_err(|e| TransportError::from_io(e, rank, deadline))?;
        let (kind, payload) =
            read_frame(stream).map_err(|e| TransportError::from_io(e, rank, deadline))?;
        decode_samples(kind, &payload, ids.len())
    }
}

/// Decode a PSAMP (or PSAMP_SHM) response into per-id hits.
fn decode_samples(
    kind: u8,
    payload: &[u8],
    expect: usize,
) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
    if kind != PSAMP {
        return Err(TransportError::Malformed("unexpected peer frame kind"));
    }
    let mut r = WireReader::new(payload);
    let n = r.u32()? as usize;
    if n != expect {
        return Err(TransportError::Malformed("sample count mismatch"));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if r.u8()? == 0 {
            out.push(None);
            continue;
        }
        let label = r.u16()?;
        let len = r.u32()? as usize;
        if len > MAX_FRAME {
            return Err(TransportError::Malformed("sample over-long"));
        }
        out.push(Some((label, r.take(len)?.to_vec())));
    }
    Ok(out)
}

impl PeerTransport for UdsPeers {
    fn serves_local(&self, learner: usize) -> bool {
        learner / self.g == self.my_rank
    }

    fn fetch_from_owner(
        &self,
        owner: usize,
        ids: &[u32],
        deadline: Option<Duration>,
    ) -> Result<Vec<Option<(u16, Vec<u8>)>>, TransportError> {
        let rank = owner / self.g;
        let slot = self
            .slots
            .get(rank)
            .ok_or(TransportError::Malformed("owner rank out of range"))?;
        if slot.dead.load(Ordering::Acquire) {
            return Err(TransportError::PeerClosed { peer: rank });
        }
        let mut guard = slot.conn.lock().unwrap();
        let had_cached = guard.is_some();
        if guard.is_none() {
            let s = UnixStream::connect(&self.paths[rank])
                .map_err(|e| TransportError::from_io(e, rank, deadline))?;
            *guard = Some(s);
        }
        let mut stream = guard.take().unwrap();
        match self.exchange(&mut stream, owner, ids, deadline) {
            Ok(out) => {
                *guard = Some(stream);
                Ok(out)
            }
            Err(TransportError::PeerClosed { .. }) if had_cached => {
                // The cached stream was stale (peer restarted since the
                // last fetch). Dial fresh and retry exactly once: the
                // request is idempotent and no response byte was
                // accepted from the dead stream, so nothing can be
                // double-counted.
                let mut fresh = UnixStream::connect(&self.paths[rank])
                    .map_err(|e| TransportError::from_io(e, rank, deadline))?;
                let out = self.exchange(&mut fresh, owner, ids, deadline)?;
                *guard = Some(fresh);
                Ok(out)
            }
            Err(e) => Err(e),
        }
    }

    fn mark_dead(&self, rank: usize) {
        if let Some(slot) = self.slots.get(rank) {
            slot.dead.store(true, Ordering::Release);
            *slot.conn.lock().unwrap() = None;
        }
    }

    fn mark_alive(&self, rank: usize) {
        if let Some(slot) = self.slots.get(rank) {
            slot.dead.store(false, Ordering::Release);
            *slot.conn.lock().unwrap() = None;
        }
    }
}

/// UDS server: serves this process's learner caches to its peers.
///
/// One accept thread, one handler thread per peer connection. Requests
/// are [`PFETCH`] frames (target learner + sample ids); the reply is one
/// [`PSAMP`] frame with per-id hit flags, so a short read on the client
/// is always distinguishable from a miss.
pub struct PeerServer {
    path: PathBuf,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl PeerServer {
    /// Bind `path` (unlinking any stale socket first) and serve
    /// `caches`, a map from *global* learner id to that learner's stack.
    pub fn start(
        path: PathBuf,
        caches: HashMap<usize, Arc<CacheStack>>,
    ) -> io::Result<PeerServer> {
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = shutdown.clone();
        let caches = Arc::new(caches);
        let accept_thread = thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((conn, _)) => {
                        let caches = caches.clone();
                        let stop = stop.clone();
                        thread::spawn(move || serve_conn(conn, &caches, &stop));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(PeerServer {
            path,
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for PeerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_conn(mut conn: UnixStream, caches: &HashMap<usize, Arc<CacheStack>>, stop: &AtomicBool) {
    // Bounded reads so the handler re-checks the shutdown flag instead
    // of parking forever on an idle client.
    let _ = conn.set_read_timeout(Some(Duration::from_millis(100)));
    while !stop.load(Ordering::Acquire) {
        let (kind, payload) = match read_frame(&mut conn) {
            Ok(f) => f,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return, // EOF or protocol error: client is gone.
        };
        if kind != PFETCH {
            return;
        }
        let mut r = WireReader::new(&payload);
        let (learner, ids) = match (|| {
            let learner = r.u32()? as usize;
            let ids = r.vec_u32()?;
            Ok::<_, TransportError>((learner, ids))
        })() {
            Ok(v) => v,
            Err(_) => return,
        };
        let mut resp = Wire::new();
        resp.u32(ids.len() as u32);
        let stack = caches.get(&learner);
        for id in &ids {
            match stack.and_then(|s| s.get(*id)) {
                Some(sample) => {
                    let bytes = sample.bytes.as_slice();
                    resp.u8(1).u16(sample.label).u32(bytes.len() as u32).bytes(bytes);
                }
                None => {
                    resp.u8(0);
                }
            }
        }
        let _ = conn.set_write_timeout(Some(Duration::from_secs(30)));
        if write_frame(&mut conn, PSAMP, &resp.take()).is_err() {
            return;
        }
    }
}

/// Shared-memory payload ring (feature `shm-ring`): the server bump-
/// allocates payload bytes into an mmap-shared file; clients map the
/// same file read-only and build zero-copy `SampleBytes` views. Kept
/// deliberately simple — a full ring would recycle; this segment serves
/// an epoch's working set and falls back to inline frames when full.
#[cfg(feature = "shm-ring")]
pub mod shm {
    use crate::storage::SampleBytes;
    use std::fs::{File, OpenOptions};
    use std::io;
    use std::path::Path;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    pub struct ShmWriter {
        file: File,
        capacity: u64,
        cursor: AtomicU64,
    }

    impl ShmWriter {
        pub fn create(path: &Path, capacity: u64) -> io::Result<ShmWriter> {
            let file = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(path)?;
            file.set_len(capacity)?;
            Ok(ShmWriter { file, capacity, cursor: AtomicU64::new(0) })
        }

        /// Reserve + write; returns the segment offset, or `None` when
        /// the ring is full (caller falls back to an inline frame).
        pub fn push(&self, bytes: &[u8]) -> Option<u64> {
            use std::os::unix::fs::FileExt;
            let len = bytes.len() as u64;
            let off = self.cursor.fetch_add(len, Ordering::Relaxed);
            if off + len > self.capacity {
                return None;
            }
            self.file.write_all_at(bytes, off).ok()?;
            Some(off)
        }
    }

    pub struct ShmReader {
        map: Arc<crate::storage::bytes::Mmap>,
    }

    impl ShmReader {
        pub fn open(path: &Path) -> io::Result<ShmReader> {
            let file = File::open(path)?;
            let map = crate::storage::bytes::Mmap::map_shared(&file)?;
            Ok(ShmReader { map: Arc::new(map) })
        }

        /// Zero-copy view into the ring.
        pub fn view(&self, off: u64, len: u32) -> SampleBytes {
            SampleBytes::from_map(self.map.clone(), off as usize, len as usize)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Policy;
    use crate::storage::Sample;

    fn tmp_sock(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "dlio-tsock-{tag}-{}-{:?}.sock",
            std::process::id(),
            thread::current().id()
        ))
    }

    fn stack_with(ids: &[(u32, u16, Vec<u8>)]) -> Arc<CacheStack> {
        let stack = Arc::new(CacheStack::mem_only(u64::MAX, Policy::InsertOnly));
        for (id, label, bytes) in ids {
            stack.insert(Arc::new(Sample {
                id: *id,
                bytes: bytes.clone().into(),
                label: *label,
            }));
        }
        stack
    }

    #[test]
    fn frame_roundtrip_and_wire_codec() {
        let mut buf = Vec::new();
        let mut w = Wire::new();
        w.u8(7).u16(300).u32(1 << 20).u64(1 << 40).f32(0.5).vec_u32(&[1, 2, 3]);
        write_frame(&mut buf, PFETCH, &w.take()).unwrap();
        let (kind, payload) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(kind, PFETCH);
        let mut r = WireReader::new(&payload);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 1 << 20);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 0.5);
        assert_eq!(r.vec_u32().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn oversized_and_truncated_frames_are_typed_errors() {
        // Header announcing more than MAX_FRAME must not allocate.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut &huge[..]).is_err());
        // Truncated payload is UnexpectedEof, not a panic.
        let mut buf = Vec::new();
        write_frame(&mut buf, PSAMP, &[1, 2, 3, 4]).unwrap();
        buf.truncate(buf.len() - 2);
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // WireReader over-reads are Malformed errors.
        let mut r = WireReader::new(&[1, 2]);
        assert!(matches!(r.u32(), Err(TransportError::Malformed(_))));
    }

    #[test]
    fn uds_serves_hits_and_misses() {
        let path = tmp_sock("serve");
        let mut caches = HashMap::new();
        caches.insert(3usize, stack_with(&[(10, 4, vec![1, 2, 3]), (11, 5, vec![9])]));
        let _server = PeerServer::start(path.clone(), caches).unwrap();
        let peers = UdsPeers::new(0, 2, vec![path.clone(), path.clone()]);
        // Owner 3 lives on rank 1 (g = 2).
        assert!(!peers.serves_local(3));
        assert!(peers.serves_local(1));
        let out = peers
            .fetch_from_owner(3, &[10, 99, 11], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out[0], Some((4, vec![1, 2, 3])));
        assert_eq!(out[1], None);
        assert_eq!(out[2], Some((5, vec![9])));
    }

    /// Satellite: EOF racing a completed transfer. The peer writes the
    /// complete response and *immediately* closes the socket. The first
    /// fetch must succeed exactly once (the samples were delivered); the
    /// next fetch on the now-dead cached connection must surface peer
    /// death — never a duplicated success.
    #[test]
    fn eof_after_complete_response_does_not_double_count() {
        let path = tmp_sock("eofrace");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let server = thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let (kind, payload) = read_frame(&mut conn).unwrap();
            assert_eq!(kind, PFETCH);
            let mut r = WireReader::new(&payload);
            let _learner = r.u32().unwrap();
            let ids = r.vec_u32().unwrap();
            let mut resp = Wire::new();
            resp.u32(ids.len() as u32);
            for _ in &ids {
                resp.u8(1).u16(1).u32(2).bytes(&[0xAB, 0xCD]);
            }
            write_frame(&mut conn, PSAMP, &resp.take()).unwrap();
            // Close right behind the response: EOF races the client read.
            drop(conn);
            // Listener drops here: no further connection is possible.
        });
        let peers = UdsPeers::new(1, 1, vec![path.clone(), path.clone()]);
        let out = peers
            .fetch_from_owner(0, &[5, 6], Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|s| s == &Some((1, vec![0xAB, 0xCD]))));
        server.join().unwrap();
        let _ = std::fs::remove_file(&path);
        // The cached connection is dead and the listener is gone: the
        // retry dial fails too, so this is PeerClosed — the transfer is
        // not silently re-served or double-counted.
        let err = peers
            .fetch_from_owner(0, &[5], Some(Duration::from_secs(1)))
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 0 }), "{err}");
    }

    /// Satellite: a peer that died before ever serving (freeze-then-die
    /// at the transport level) surfaces as PeerClosed, mapped from the
    /// failed connect.
    #[test]
    fn connect_to_dead_peer_is_peer_closed() {
        let path = tmp_sock("deadpeer");
        let _ = std::fs::remove_file(&path);
        let peers = UdsPeers::new(0, 1, vec![tmp_sock("self"), path]);
        let err = peers
            .fetch_from_owner(1, &[0], Some(Duration::from_millis(100)))
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }), "{err}");
        // And once marked dead, the fetch short-circuits without dialing.
        peers.mark_dead(1);
        let err = peers.fetch_from_owner(1, &[0], None).unwrap_err();
        assert!(matches!(err, TransportError::PeerClosed { peer: 1 }));
        peers.mark_alive(1);
    }

    #[test]
    fn read_deadline_maps_to_transfer_stall() {
        let path = tmp_sock("stall");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        // A server that accepts and then never replies.
        let silent = thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            thread::sleep(Duration::from_millis(400));
            drop(conn);
        });
        let peers = UdsPeers::new(1, 1, vec![path.clone(), path.clone()]);
        let err = peers
            .fetch_from_owner(0, &[1], Some(Duration::from_millis(50)))
            .unwrap_err();
        match err {
            TransportError::Stall(s) => {
                assert_eq!(s.kind, StallKind::Transfer);
                let msg = s.to_string();
                assert!(msg.contains("transfer wait exceeded its deadline"), "{msg}");
            }
            other => panic!("expected transfer stall, got {other}"),
        }
        silent.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
