//! The paper's analytical performance model (§IV, Eqs. 1–8).
//!
//! Closed forms for training / I/O / preprocessing time as functions of
//! scale p, plus the three sample-I/O variants: plain storage loading,
//! distributed caching (Eq. 7), and locality-aware loading (Eq. 8). Used
//! to predict the Fig. 1 plateau and the Eq. 5 crossover, and
//! cross-validated against the discrete-event simulator in
//! `rust/tests/sim_vs_analytic.rs`.
//!
//! Unit conventions: D in *samples*; V and U in samples/sec *per node*;
//! R, R_c, R_b in bytes/sec with `avg_bytes` converting.

/// Model parameters (uppercase letters of §IV).
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    /// Dataset size D, in samples.
    pub d_samples: f64,
    /// Mean sample size in bytes.
    pub avg_bytes: f64,
    /// Max training rate V of one node, samples/sec.
    pub v: f64,
    /// Aggregate storage I/O rate R, bytes/sec.
    pub r: f64,
    /// Remote-cache I/O rate R_c (per link), bytes/sec.
    pub rc: f64,
    /// Load-balancing I/O rate R_b (the paper sets R_b = R_c), bytes/sec.
    pub rb: f64,
    /// Max preprocessing rate U of one node, samples/sec.
    pub u: f64,
    /// Cached fraction α of the dataset (aggregated cache, both tiers).
    pub alpha: f64,
    /// Fraction of the dataset held on the SSD tier of the hierarchical
    /// cache stack (§III-C/§VIII: "datasets too large to fit in the local
    /// DRAM can be cached in SSDs"). The DRAM share is `alpha −
    /// alpha_disk`; 0 keeps the original all-DRAM Eqs. 7/8.
    pub alpha_disk: f64,
    /// Per-node SSD read bandwidth serving disk-tier hits, bytes/sec.
    pub r_disk: f64,
    /// Balance traffic ratio β (Fig. 6: ~0.03–0.07).
    pub beta: f64,
    /// Async-supply extension (DESIGN.md §15): per-request storage device
    /// latency, seconds. 0 keeps the bandwidth-only Eqs. 2/7/8 exactly.
    pub l_storage: f64,
    /// Samples coalesced per storage request (run coalescing); the latency
    /// term divides by it. Values < 1 are treated as 1.
    pub g_storage: f64,
    /// Storage requests in flight per submission wave (queue depth); the
    /// latency term divides by it. Values < 1 are treated as 1 (blocking
    /// pread, one request at a time).
    pub q_storage: f64,
}

impl ModelParams {
    /// Storage rate in samples/sec.
    pub fn r_samples(&self) -> f64 {
        self.r / self.avg_bytes
    }

    /// Eq. (1): training time of an epoch on p nodes.
    pub fn training_time(&self, p: usize) -> f64 {
        self.d_samples / (p as f64 * self.v)
    }

    /// Async-supply latency term: reading `frac` of the dataset issues
    /// `frac·D/g` coalesced requests at `l` seconds each, overlapped `q`
    /// deep by the submission waves — so the front-end serves it in
    /// `frac·D·l/(g·q)` seconds on top of the bandwidth bound. 0 when
    /// `l_storage` is 0 (the paper's original bandwidth-only model).
    pub fn supply_latency_time(&self, frac: f64) -> f64 {
        if self.l_storage <= 0.0 || frac <= 0.0 {
            return 0.0;
        }
        frac * self.d_samples * self.l_storage
            / (self.g_storage.max(1.0) * self.q_storage.max(1.0))
    }

    /// Eq. (2): sample I/O time, plain loading (all from storage), plus
    /// the async-supply latency term.
    pub fn io_time_plain(&self) -> f64 {
        self.d_samples * self.avg_bytes / self.r + self.supply_latency_time(1.0)
    }

    /// Eq. (3): preprocessing time on p nodes.
    pub fn preprocess_time(&self, p: usize) -> f64 {
        self.d_samples / (p as f64 * self.u)
    }

    /// Eq. (4): data loading time = I/O + preprocessing.
    pub fn loading_time_plain(&self, p: usize) -> f64 {
        self.io_time_plain() + self.preprocess_time(p)
    }

    /// Eq. (5): the crossover scale p* = R/V below which training time
    /// dominates the true cost.
    pub fn crossover_p(&self) -> f64 {
        self.r_samples() / self.v
    }

    /// Eq. (6): true epoch cost with loading overlapped with training.
    pub fn true_cost_plain(&self, p: usize) -> f64 {
        self.training_time(p).max(self.loading_time_plain(p))
    }

    /// Hierarchical cache term extending Eqs. (7)/(8): the disk-tier
    /// share of cache hits is read from the owners' local SSDs — p SSDs
    /// in parallel, so the term scales with p like training does. 0 when
    /// the stack is all-DRAM (the paper's original equations).
    pub fn disk_read_time(&self, p: usize) -> f64 {
        let share = self.alpha_disk.clamp(0.0, self.alpha);
        if share <= 0.0 || self.r_disk <= 0.0 {
            return 0.0;
        }
        share * self.d_samples * self.avg_bytes / (p as f64 * self.r_disk)
    }

    /// Eq. (7): sample I/O time with distributed caching, extended with
    /// the hierarchical disk-tier read term.
    pub fn io_time_distcache(&self, p: usize) -> f64 {
        let d_bytes = self.d_samples * self.avg_bytes;
        let storage = (1.0 - self.alpha) * d_bytes / self.r
            + self.supply_latency_time(1.0 - self.alpha);
        let remote = self.alpha * d_bytes / self.rc
            * ((p as f64 - 1.0) / p as f64);
        storage + remote + self.disk_read_time(p)
    }

    /// Eq. (8): sample I/O time with locality-aware loading, extended
    /// with the hierarchical disk-tier read term (which is why it now
    /// takes p: the SSD reads parallelize across nodes).
    pub fn io_time_loc(&self, p: usize) -> f64 {
        let d_bytes = self.d_samples * self.avg_bytes;
        let storage = (1.0 - self.alpha) * d_bytes / self.r
            + self.supply_latency_time(1.0 - self.alpha);
        let balance = self.alpha * d_bytes / self.rb * self.beta;
        storage + balance + self.disk_read_time(p)
    }

    /// True cost under distributed caching.
    pub fn true_cost_distcache(&self, p: usize) -> f64 {
        self.training_time(p)
            .max(self.io_time_distcache(p) + self.preprocess_time(p))
    }

    /// True cost under locality-aware loading.
    pub fn true_cost_loc(&self, p: usize) -> f64 {
        self.training_time(p)
            .max(self.io_time_loc(p) + self.preprocess_time(p))
    }

    /// Loading-only cost (no training), the Figs. 8–11 regime.
    pub fn loading_only_plain(&self, p: usize) -> f64 {
        self.loading_time_plain(p)
    }

    pub fn loading_only_loc(&self, p: usize) -> f64 {
        self.io_time_loc(p) + self.preprocess_time(p)
    }
}

/// Lassen-calibrated defaults (DESIGN.md §6): V from 4×V100 ResNet50
/// (~1440 samples/s/node), R chosen so the Fig. 1 plateau starts just past
/// 16 nodes and Fig. 12 shows ~1.9x at 64 (Eq. 5), EDR-class links, U from
/// the 34x-headline-implied ~5000 samples/s/node preprocess rate.
pub fn lassen_imagenet() -> ModelParams {
    ModelParams {
        d_samples: 1_281_167.0,
        avg_bytes: 117.0 * 1024.0,
        v: 1_440.0,
        r: 5.2e9,
        rc: 12.5e9,
        rb: 12.5e9,
        u: 5_000.0,
        alpha: 1.0,
        alpha_disk: 0.0,
        r_disk: 2.4e9,
        beta: 0.035,
        l_storage: 0.0,
        g_storage: 1.0,
        q_storage: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> ModelParams {
        lassen_imagenet()
    }

    #[test]
    fn training_time_scales_inversely() {
        let m = p();
        let t2 = m.training_time(2);
        let t8 = m.training_time(8);
        assert!((t2 / t8 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn loading_plateaus_at_io_bound() {
        let m = p();
        // As p grows, loading time approaches the constant D·b/R (Fig. 1).
        let l16 = m.loading_time_plain(16);
        let l256 = m.loading_time_plain(256);
        let floor = m.io_time_plain();
        assert!(l16 > l256);
        assert!(l256 >= floor);
        assert!((l256 - floor) / floor < 0.3);
    }

    #[test]
    fn crossover_matches_fig1() {
        let m = p();
        let pc = m.crossover_p();
        // Calibrated so the plateau starts around 16 nodes.
        assert!(
            (10.0..32.0).contains(&pc),
            "crossover {pc} not in the Fig. 1 regime"
        );
        // Below crossover training dominates; above, loading does.
        let below = (pc * 0.5) as usize;
        let above = (pc * 4.0) as usize;
        assert!(m.training_time(below) >= m.loading_time_plain(below) * 0.8);
        assert!(m.loading_time_plain(above) > m.training_time(above));
    }

    #[test]
    fn eq7_distcache_beats_plain_when_rc_large() {
        let m = p();
        for nodes in [16, 64, 256] {
            assert!(m.io_time_distcache(nodes) < m.io_time_plain());
        }
    }

    #[test]
    fn eq8_loc_beats_distcache_at_scale() {
        let m = p();
        // (p-1)/p ≈ 1 ≫ β, so Loc's second term is ~β× the DistCache one.
        for nodes in [16, 64, 256] {
            let dc = m.io_time_distcache(nodes);
            let loc = m.io_time_loc(nodes);
            assert!(
                loc < dc * 0.2,
                "p={nodes}: loc={loc} not ≪ distcache={dc}"
            );
        }
    }

    #[test]
    fn alpha_zero_degenerates_to_plain() {
        let mut m = p();
        m.alpha = 0.0;
        for nodes in [4, 64] {
            assert!((m.io_time_distcache(nodes) - m.io_time_plain()).abs() < 1e-6);
            assert!((m.io_time_loc(nodes) - m.io_time_plain()).abs() < 1e-6);
        }
    }

    #[test]
    fn loc_scales_with_nodes_while_plain_does_not() {
        let m = p();
        // Paper Fig. 8 headline: Loc keeps scaling, Reg plateaus.
        let plain_speedup =
            m.loading_only_plain(16) / m.loading_only_plain(256);
        let loc_speedup = m.loading_only_loc(16) / m.loading_only_loc(256);
        assert!(plain_speedup < 2.0, "plain speedup {plain_speedup}");
        assert!(loc_speedup > 5.0, "loc speedup {loc_speedup}");
    }

    #[test]
    fn loc_vs_plain_headline_factor() {
        let m = p();
        // At 256 nodes the paper reports ~34x; the analytic model should
        // put the ratio in tens.
        let ratio = m.loading_only_plain(256) / m.loading_only_loc(256);
        assert!((10.0..120.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn hierarchical_cache_term_degenerates_when_all_dram() {
        // alpha_disk = 0 must reproduce the paper's original Eqs. 7/8
        // bit-for-bit — the hierarchy is a strict extension.
        let m = p();
        assert_eq!(m.alpha_disk, 0.0);
        for nodes in [4, 16, 64, 256] {
            assert_eq!(m.disk_read_time(nodes), 0.0);
        }
        let mut t = m;
        t.alpha_disk = 0.5;
        t.r_disk = 0.0; // no SSD: term defined as 0 rather than ∞
        assert_eq!(t.disk_read_time(16), 0.0);
    }

    #[test]
    fn disk_tier_term_scales_with_p_and_keeps_loc_scaling() {
        // Half the dataset on SSD (DRAM exhausted at α=1): the disk term
        // parallelizes across nodes, so Loc keeps scaling — the §VIII
        // motivation for the hierarchy.
        let mut m = p();
        m.alpha_disk = 0.5;
        let d16 = m.disk_read_time(16);
        let d256 = m.disk_read_time(256);
        assert!((d16 / d256 - 16.0).abs() < 1e-9, "disk term must be ∝ 1/p");
        // Tiered Loc costs more than all-DRAM Loc but still beats plain
        // loading by a wide margin at scale.
        let dram = p();
        for nodes in [16, 64, 256] {
            assert!(m.io_time_loc(nodes) > dram.io_time_loc(nodes));
            assert!(
                m.loading_only_loc(nodes) < m.loading_only_plain(nodes),
                "p={nodes}: tiered Loc must still beat plain loading"
            );
        }
        // ... and the paper's headline regime survives the SSD tier.
        let ratio = m.loading_only_plain(256) / m.loading_only_loc(256);
        assert!((10.0..120.0).contains(&ratio), "256-node ratio {ratio}");
        // alpha_disk is clamped to the cached fraction.
        let mut c = p();
        c.alpha = 0.3;
        c.alpha_disk = 0.9;
        assert!(
            (c.disk_read_time(16)
                - 0.3 * c.d_samples * c.avg_bytes / (16.0 * c.r_disk))
                .abs()
                < 1e-6
        );
    }

    #[test]
    fn supply_latency_degenerates_when_zero() {
        // l_storage = 0 must reproduce the bandwidth-only equations
        // bit-for-bit — the async-supply term is a strict extension.
        let m = p();
        assert_eq!(m.l_storage, 0.0);
        assert_eq!(m.supply_latency_time(1.0), 0.0);
        assert_eq!(m.io_time_plain(), m.d_samples * m.avg_bytes / m.r);
        let mut t = m;
        t.l_storage = 1e-3;
        t.alpha = 1.0; // fully cached: no storage requests remain
        assert_eq!(t.supply_latency_time(1.0 - t.alpha), 0.0);
        assert_eq!(t.io_time_loc(16), m.io_time_loc(16));
    }

    #[test]
    fn coalescing_and_queue_depth_amortize_request_latency() {
        let mut m = p();
        m.l_storage = 1e-3;
        let blocking = m.supply_latency_time(1.0);
        assert!((blocking - m.d_samples * 1e-3).abs() < 1e-6);
        assert!(m.io_time_plain() > p().io_time_plain());
        // Coalescing g samples per request and q-deep waves each divide
        // the term; together they compose multiplicatively.
        m.g_storage = 8.0;
        m.q_storage = 4.0;
        let waved = m.supply_latency_time(1.0);
        assert!((blocking / waved - 32.0).abs() < 1e-6);
        // Sub-1 values clamp to 1 rather than inflating the term.
        m.g_storage = 0.0;
        m.q_storage = 0.5;
        assert!((m.supply_latency_time(1.0) - blocking).abs() < 1e-6);
        // The uncached fraction scales the request count (Eqs. 7/8).
        m.g_storage = 1.0;
        m.q_storage = 1.0;
        m.alpha = 0.75;
        let partial = m.supply_latency_time(1.0 - m.alpha);
        assert!((partial / blocking - 0.25).abs() < 1e-6);
    }
}
