//! Configuration system: a small dependency-free CLI argument parser plus
//! typed option accessors, used by the `dlio` launcher, the examples and
//! the bench binaries.
//!
//! Grammar: `dlio <subcommand> [--key value]... [--flag]...`
//! Every option also has an environment fallback `DLIO_<KEY>` (upper-cased,
//! dashes → underscores) so benches can be tuned without editing code.

use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand unless
    /// it starts with `--`).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                // `--key=value` or `--key value` or boolean flag.
                if let Some((k, v)) = key.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    args.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    fn lookup(&self, key: &str) -> Option<String> {
        if let Some(v) = self.opts.get(key) {
            return Some(v.clone());
        }
        let env_key =
            format!("DLIO_{}", key.to_ascii_uppercase().replace('-', "_"));
        std::env::var(env_key).ok()
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.lookup(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.lookup(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{key} {v:?}: not an integer"))
            }
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{key} {v:?}: not an integer"))
            }
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{key} {v:?}: not a number"))
            }
        }
    }

    /// Byte-quantity option: plain integers, `k`/`m`/`g` (and `kb`/`kib`
    /// etc.) suffixed sizes — "512k", "1.5GiB" — or "max" for `u64::MAX`.
    /// Cache/spill capacities read through this so CLI users don't count
    /// zeros.
    pub fn bytes_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => parse_bytes(&v)
                .with_context(|| format!("--{key} {v:?}: not a byte size")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || std::env::var(format!(
                "DLIO_{}",
                key.to_ascii_uppercase().replace('-', "_")
            ))
            .map(|v| v == "1" || v == "true")
            .unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of integers ("2,4,8").
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.lookup(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .with_context(|| format!("--{key}: bad item {t:?}"))
                })
                .collect(),
        }
    }
}

/// Parse a human byte size: "4096", "512k", "16m", "1.5g", "2GiB", "max".
fn parse_bytes(raw: &str) -> Result<u64> {
    let t = raw.trim().to_ascii_lowercase();
    if t == "max" {
        return Ok(u64::MAX);
    }
    let suffixes: [(&str, u64); 9] = [
        ("kib", 1 << 10),
        ("mib", 1 << 20),
        ("gib", 1 << 30),
        ("kb", 1 << 10),
        ("mb", 1 << 20),
        ("gb", 1 << 30),
        ("k", 1 << 10),
        ("m", 1 << 20),
        ("g", 1 << 30),
    ];
    let (digits, mult) = suffixes
        .iter()
        .find_map(|&(suf, mult)| {
            t.strip_suffix(suf).map(|rest| (rest, mult))
        })
        .unwrap_or((t.as_str(), 1));
    let n: f64 = digits
        .trim()
        .parse()
        .with_context(|| format!("bad byte quantity {raw:?}"))?;
    ensure!(
        n.is_finite() && n >= 0.0,
        "byte quantity {raw:?} must be non-negative"
    );
    Ok((n * mult as f64) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --p 4 --epochs=3 --verbose --dir /tmp/x");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("p", 1).unwrap(), 4);
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 3);
        assert_eq!(a.str_or("dir", ""), "/tmp/x");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("sim");
        assert_eq!(a.usize_or("nodes", 16).unwrap(), 16);
        assert_eq!(a.f64_or("alpha", 1.0).unwrap(), 1.0);
        assert_eq!(a.str_or("sampler", "loc"), "loc");
    }

    #[test]
    fn lists_parse() {
        let a = parse("sim --nodes 2,8, 32");
        // note: "2,8," with trailing item "32" positional — keep simple:
        let b = parse("sim --nodes 2,8,32");
        assert_eq!(b.usize_list_or("nodes", &[]).unwrap(), vec![2, 8, 32]);
        assert!(a.usize_list_or("nodes", &[]).is_err() || !a.positional().is_empty());
    }

    #[test]
    fn byte_quantities_parse_with_suffixes() {
        let a = parse(
            "train --cache-bytes 512k --disk-cache-bytes 1.5g --raw 4096 \
             --cap max --pad 2MiB",
        );
        assert_eq!(a.bytes_or("cache-bytes", 0).unwrap(), 512 * 1024);
        assert_eq!(
            a.bytes_or("disk-cache-bytes", 0).unwrap(),
            (1.5 * (1u64 << 30) as f64) as u64
        );
        assert_eq!(a.bytes_or("raw", 0).unwrap(), 4096);
        assert_eq!(a.bytes_or("cap", 0).unwrap(), u64::MAX);
        assert_eq!(a.bytes_or("pad", 0).unwrap(), 2 << 20);
        assert_eq!(a.bytes_or("absent", 77).unwrap(), 77);
        let bad = parse("train --cache-bytes nope");
        assert!(bad.bytes_or("cache-bytes", 0).is_err());
        let neg = parse("train --cache-bytes -1k");
        assert!(neg.bytes_or("cache-bytes", 0).is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --p nope");
        assert!(a.usize_or("p", 1).is_err());
    }

    #[test]
    fn no_subcommand_when_leading_flag() {
        let a = parse("--p 3");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.usize_or("p", 0).unwrap(), 3);
    }
}
