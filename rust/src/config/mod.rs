//! Configuration system: a small dependency-free CLI argument parser plus
//! typed option accessors, used by the `dlio` launcher, the examples and
//! the bench binaries.
//!
//! Grammar: `dlio <subcommand> [--key value]... [--flag]...`
//! Every option also has an environment fallback `DLIO_<KEY>` (upper-cased,
//! dashes → underscores) so benches can be tuned without editing code.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand unless
    /// it starts with `--`).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                // `--key=value` or `--key value` or boolean flag.
                if let Some((k, v)) = key.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    args.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    fn lookup(&self, key: &str) -> Option<String> {
        if let Some(v) = self.opts.get(key) {
            return Some(v.clone());
        }
        let env_key =
            format!("DLIO_{}", key.to_ascii_uppercase().replace('-', "_"));
        std::env::var(env_key).ok()
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.lookup(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.lookup(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{key} {v:?}: not an integer"))
            }
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{key} {v:?}: not an integer"))
            }
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.lookup(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{key} {v:?}: not a number"))
            }
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || std::env::var(format!(
                "DLIO_{}",
                key.to_ascii_uppercase().replace('-', "_")
            ))
            .map(|v| v == "1" || v == "true")
            .unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of integers ("2,4,8").
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.lookup(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .with_context(|| format!("--{key}: bad item {t:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --p 4 --epochs=3 --verbose --dir /tmp/x");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("p", 1).unwrap(), 4);
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 3);
        assert_eq!(a.str_or("dir", ""), "/tmp/x");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("sim");
        assert_eq!(a.usize_or("nodes", 16).unwrap(), 16);
        assert_eq!(a.f64_or("alpha", 1.0).unwrap(), 1.0);
        assert_eq!(a.str_or("sampler", "loc"), "loc");
    }

    #[test]
    fn lists_parse() {
        let a = parse("sim --nodes 2,8, 32");
        // note: "2,8," with trailing item "32" positional — keep simple:
        let b = parse("sim --nodes 2,8,32");
        assert_eq!(b.usize_list_or("nodes", &[]).unwrap(), vec![2, 8, 32]);
        assert!(a.usize_list_or("nodes", &[]).is_err() || !a.positional().is_empty());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --p nope");
        assert!(a.usize_or("p", 1).is_err());
    }

    #[test]
    fn no_subcommand_when_leading_flag() {
        let a = parse("--p 3");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.usize_or("p", 0).unwrap(), 3);
    }
}
