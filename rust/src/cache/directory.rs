//! The replicated cache directory (paper §V-A), lock-free.
//!
//! Tracks, for every sample id, which learner's cache holds it. The paper
//! assumes "a cache directory exists for tracking sample locations, and the
//! directory is duplicated across all learners and stays the same (i.e. no
//! cache replacement) after populating caches in the first epoch" — so the
//! directory here is a dense table consulted once per sample per step.
//!
//! The table is a `Vec<AtomicU32>`: owner lookups on the fetch hot path are
//! a single relaxed atomic load (no `RwLock`/`Mutex` anywhere — DESIGN.md
//! §4), and population writes are last-writer-wins swaps. The directory is
//! a routing *hint*, not the source of truth: the owning cache's own
//! synchronization protects payloads, and a stale entry (e.g. a Fifo
//! eviction on the owner) is repaired by the fetch path via
//! [`clear_owner_if`].
//!
//! [`clear_owner_if`]: CacheDirectory::clear_owner_if

use super::Tier;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Sentinel for "not cached anywhere".
const NONE: u32 = u32::MAX;

/// High bit of an entry marking a *disk-tier* resident (hierarchical cache
/// stack); the owner id lives in the low bits. Checked after the `NONE`
/// sentinel (which has every bit set).
const DISK_BIT: u32 = 1 << 30;
const OWNER_MASK: u32 = DISK_BIT - 1;

fn encode(learner: usize, tier: Tier) -> u32 {
    debug_assert!(
        (learner as u64) < DISK_BIT as u64,
        "learner id {learner} exceeds the directory's owner range"
    );
    learner as u32
        | match tier {
            Tier::Mem => 0,
            Tier::Disk => DISK_BIT,
        }
}

/// Dense sample-id -> owning-learner map. All methods take `&self`; share
/// it behind a plain `Arc`.
#[derive(Debug)]
pub struct CacheDirectory {
    owner: Vec<AtomicU32>,
    cached: AtomicU64,
}

impl Clone for CacheDirectory {
    /// Snapshot clone (per-entry relaxed loads).
    fn clone(&self) -> Self {
        CacheDirectory {
            owner: self
                .owner
                .iter()
                .map(|o| AtomicU32::new(o.load(Ordering::Relaxed)))
                .collect(),
            cached: AtomicU64::new(self.cached.load(Ordering::Relaxed)),
        }
    }
}

impl CacheDirectory {
    pub fn new(n_samples: u64) -> Self {
        let mut owner = Vec::with_capacity(n_samples as usize);
        owner.resize_with(n_samples as usize, || AtomicU32::new(NONE));
        CacheDirectory { owner, cached: AtomicU64::new(0) }
    }

    pub fn n_samples(&self) -> u64 {
        self.owner.len() as u64
    }

    /// Which learner caches `sample`, if any. One relaxed atomic load —
    /// the lock-free hot path. Tier-agnostic (the owner id is masked out
    /// of the entry); use [`owner_tier`] when the hit-cost class matters.
    ///
    /// [`owner_tier`]: CacheDirectory::owner_tier
    #[inline]
    pub fn owner(&self, sample: u32) -> Option<usize> {
        match self.owner.get(sample as usize) {
            Some(o) => match o.load(Ordering::Relaxed) {
                NONE => None,
                j => Some((j & OWNER_MASK) as usize),
            },
            None => None,
        }
    }

    /// Which learner caches `sample` and in which tier of its stack
    /// (hierarchical capacity: DRAM hits and SSD hits cost differently —
    /// the Eq. 7/8 split the sim and analytic model mirror).
    #[inline]
    pub fn owner_tier(&self, sample: u32) -> Option<(usize, Tier)> {
        match self.owner.get(sample as usize) {
            Some(o) => match o.load(Ordering::Relaxed) {
                NONE => None,
                j => Some((
                    (j & OWNER_MASK) as usize,
                    if j & DISK_BIT != 0 { Tier::Disk } else { Tier::Mem },
                )),
            },
            None => None,
        }
    }

    /// Record that `learner` caches `sample` (in its DRAM tier).
    /// Idempotent; re-assignment is a logic error under the paper's
    /// no-replacement policy (but tolerated as last-writer-wins to keep
    /// population code simple).
    pub fn set_owner(&self, sample: u32, learner: usize) {
        self.set_owner_tier(sample, learner, Tier::Mem);
    }

    /// As [`set_owner`], recording which tier of the owner's stack holds
    /// the sample. Write-behind spills publish their claim with
    /// `Tier::Disk` *after* the SSD write commits.
    ///
    /// [`set_owner`]: CacheDirectory::set_owner
    pub fn set_owner_tier(&self, sample: u32, learner: usize, tier: Tier) {
        let prev = self.owner[sample as usize]
            .swap(encode(learner, tier), Ordering::Relaxed);
        if prev == NONE {
            self.cached.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Repair a stale entry: atomically clear `sample`'s owner iff it still
    /// reads `expected`. The CAS makes a concurrent re-population by a
    /// *different* learner win over the repair; a re-population by the
    /// *same* owner is indistinguishable by value (ABA), so callers must
    /// re-check the owner's cache after clearing and restore the entry via
    /// [`set_owner`] if the sample reappeared (as `FetchContext` does).
    /// Returns whether the entry was cleared.
    ///
    /// [`set_owner`]: CacheDirectory::set_owner
    pub fn clear_owner_if(&self, sample: u32, expected: usize) -> bool {
        // Tier-agnostic: clear whichever encoding (mem or disk bit)
        // currently names `expected` — a stale entry is stale regardless
        // of which tier it claimed.
        let cell = &self.owner[sample as usize];
        loop {
            let cur = cell.load(Ordering::Relaxed);
            if cur == NONE || (cur & OWNER_MASK) as usize != expected {
                return false;
            }
            if cell
                .compare_exchange_weak(
                    cur,
                    NONE,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                self.cached.fetch_sub(1, Ordering::Relaxed);
                return true;
            }
        }
    }

    /// Evict every claim naming `owner`, in either tier — the dead-owner
    /// repair (DESIGN.md §11): once a fault plan declares an owner dead,
    /// its claims only route fetches into doomed transfers, so the first
    /// learner to notice sweeps them out and subsequent plans re-route.
    /// Each entry is cleared with the same CAS as [`clear_owner_if`], so
    /// a concurrent re-population by a *live* learner wins and is kept.
    /// Returns how many entries were cleared.
    ///
    /// [`clear_owner_if`]: CacheDirectory::clear_owner_if
    pub fn evict_owner(&self, owner: usize) -> u64 {
        let mut cleared = 0u64;
        for s in 0..self.owner.len() {
            if self.clear_owner_if(s as u32, owner) {
                cleared += 1;
            }
        }
        cleared
    }

    /// Number of samples cached somewhere.
    pub fn cached_samples(&self) -> u64 {
        self.cached.load(Ordering::Relaxed)
    }

    /// The paper's α: fraction of the dataset in the aggregated cache.
    pub fn alpha(&self) -> f64 {
        self.cached_samples() as f64 / self.owner.len().max(1) as f64
    }

    /// Build a directory where learner `j` owns the contiguous block
    /// `[j*n/p, (j+1)*n/p)` — the "easily determined sample locations"
    /// population the paper recommends to avoid extra bookkeeping.
    pub fn block_populated(n_samples: u64, p: usize) -> Self {
        let dir = CacheDirectory::new(n_samples);
        let base = n_samples / p as u64;
        let rem = n_samples % p as u64;
        let mut cursor = 0u64;
        for j in 0..p {
            let take = base + u64::from((j as u64) < rem);
            for s in cursor..cursor + take {
                dir.set_owner(s as u32, j);
            }
            cursor += take;
        }
        dir
    }

    /// Build a directory where ownership is striped (`sample % p`). Both
    /// layouts are valid ("how samples are cached is not important, since
    /// the mini-batch sequences are randomly shuffled"); striping spreads
    /// shard-local I/O during population.
    pub fn striped(n_samples: u64, p: usize) -> Self {
        let dir = CacheDirectory::new(n_samples);
        for s in 0..n_samples {
            dir.set_owner(s as u32, (s % p as u64) as usize);
        }
        dir
    }

    /// Per-learner cached-sample counts (both tiers).
    pub fn counts(&self, p: usize) -> Vec<u64> {
        let mut counts = vec![0u64; p];
        for o in &self.owner {
            let o = o.load(Ordering::Relaxed);
            if o != NONE {
                counts[(o & OWNER_MASK) as usize] += 1;
            }
        }
        counts
    }

    /// (mem-tier, disk-tier) cached-sample counts across all owners — the
    /// hierarchical capacity view the sim/analytic Eq. 7 split consumes.
    pub fn tier_counts(&self) -> (u64, u64) {
        let (mut mem, mut disk) = (0u64, 0u64);
        for o in &self.owner {
            match o.load(Ordering::Relaxed) {
                NONE => {}
                v if v & DISK_BIT != 0 => disk += 1,
                _ => mem += 1,
            }
        }
        (mem, disk)
    }

    /// Fraction of the dataset cached on the *disk* tier (the hierarchical
    /// α_disk of the extended Eq. 7; `alpha() - alpha_disk()` is the DRAM
    /// share).
    pub fn alpha_disk(&self) -> f64 {
        self.tier_counts().1 as f64 / self.owner.len().max(1) as f64
    }

    /// Raw owner words (tier bits included, `u32::MAX` = unowned), one per
    /// sample — the checkpointable wire form. Per-entry relaxed loads;
    /// take it at a quiescent point (epoch boundary) for an exact image.
    pub fn snapshot_raw(&self) -> Vec<u32> {
        self.owner.iter().map(|o| o.load(Ordering::Relaxed)).collect()
    }

    /// Rebuild a directory from [`snapshot_raw`] output — step-granular
    /// resume restores ownership so post-restart plans route identically
    /// to the checkpointed run. The cached count is recomputed from the
    /// words.
    ///
    /// [`snapshot_raw`]: CacheDirectory::snapshot_raw
    pub fn from_raw(words: &[u32]) -> Self {
        let cached = words.iter().filter(|&&w| w != NONE).count() as u64;
        CacheDirectory {
            owner: words.iter().map(|&w| AtomicU32::new(w)).collect(),
            cached: AtomicU64::new(cached),
        }
    }

    /// Overwrite this directory in place from [`snapshot_raw`] words (the
    /// resume path, where the directory `Arc` is already shared with
    /// loaders and must keep its identity). Lengths must match.
    ///
    /// [`snapshot_raw`]: CacheDirectory::snapshot_raw
    pub fn restore_raw(&self, words: &[u32]) {
        assert_eq!(
            words.len(),
            self.owner.len(),
            "directory snapshot length mismatch"
        );
        let mut cached = 0u64;
        for (cell, &w) in self.owner.iter().zip(words) {
            cell.store(w, Ordering::Relaxed);
            cached += u64::from(w != NONE);
        }
        self.cached.store(cached, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn empty_directory_has_no_owners() {
        let dir = CacheDirectory::new(100);
        assert_eq!(dir.owner(0), None);
        assert_eq!(dir.owner(99), None);
        assert_eq!(dir.cached_samples(), 0);
        assert_eq!(dir.alpha(), 0.0);
    }

    #[test]
    fn set_and_lookup() {
        let dir = CacheDirectory::new(10);
        dir.set_owner(3, 2);
        dir.set_owner(7, 0);
        assert_eq!(dir.owner(3), Some(2));
        assert_eq!(dir.owner(7), Some(0));
        assert_eq!(dir.owner(4), None);
        assert_eq!(dir.cached_samples(), 2);
        // Re-setting doesn't double count.
        dir.set_owner(3, 1);
        assert_eq!(dir.cached_samples(), 2);
        assert_eq!(dir.owner(3), Some(1));
    }

    #[test]
    fn clear_owner_if_repairs_only_matching_entries() {
        let dir = CacheDirectory::new(10);
        dir.set_owner(5, 2);
        // Mismatched expectation: no-op.
        assert!(!dir.clear_owner_if(5, 1));
        assert_eq!(dir.owner(5), Some(2));
        assert_eq!(dir.cached_samples(), 1);
        // Matching expectation: cleared, count decremented.
        assert!(dir.clear_owner_if(5, 2));
        assert_eq!(dir.owner(5), None);
        assert_eq!(dir.cached_samples(), 0);
        // Clearing an already-clear entry is a no-op.
        assert!(!dir.clear_owner_if(5, 2));
        assert_eq!(dir.cached_samples(), 0);
    }

    #[test]
    fn lock_free_concurrent_population_counts_exactly() {
        let dir = std::sync::Arc::new(CacheDirectory::new(4000));
        let mut handles = Vec::new();
        for t in 0..8usize {
            let dir = std::sync::Arc::clone(&dir);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u32 {
                    dir.set_owner(t as u32 * 500 + i, t);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(dir.cached_samples(), 4000);
        assert_eq!(dir.counts(8), vec![500; 8]);
        assert_eq!(dir.alpha(), 1.0);
    }

    #[test]
    fn block_population_is_disjoint_and_complete() {
        let dir = CacheDirectory::block_populated(103, 4);
        assert_eq!(dir.alpha(), 1.0);
        let counts = dir.counts(4);
        assert_eq!(counts, vec![26, 26, 26, 25]);
        // Block property: owners are non-decreasing.
        let mut last = 0;
        for s in 0..103u32 {
            let o = dir.owner(s).unwrap();
            assert!(o >= last);
            last = o;
        }
    }

    #[test]
    fn striped_population_counts() {
        let dir = CacheDirectory::striped(10, 3);
        assert_eq!(dir.counts(3), vec![4, 3, 3]);
        assert_eq!(dir.owner(4), Some(1));
    }

    #[test]
    fn tiered_entries_round_trip_and_aggregate() {
        let dir = CacheDirectory::new(10);
        dir.set_owner_tier(1, 3, Tier::Mem);
        dir.set_owner_tier(2, 3, Tier::Disk);
        dir.set_owner_tier(3, 7, Tier::Disk);
        // Tier-agnostic lookup masks the tier bit out.
        assert_eq!(dir.owner(1), Some(3));
        assert_eq!(dir.owner(2), Some(3));
        assert_eq!(dir.owner(3), Some(7));
        assert_eq!(dir.owner_tier(1), Some((3, Tier::Mem)));
        assert_eq!(dir.owner_tier(2), Some((3, Tier::Disk)));
        assert_eq!(dir.owner_tier(3), Some((7, Tier::Disk)));
        assert_eq!(dir.owner_tier(4), None);
        assert_eq!(dir.cached_samples(), 3);
        assert_eq!(dir.counts(8), vec![0, 0, 0, 2, 0, 0, 0, 1]);
        assert_eq!(dir.tier_counts(), (1, 2));
        assert!((dir.alpha_disk() - 0.2).abs() < 1e-9);
        // A spill commit re-publishing a mem claim as disk keeps the count.
        dir.set_owner_tier(1, 3, Tier::Disk);
        assert_eq!(dir.cached_samples(), 3);
        assert_eq!(dir.tier_counts(), (0, 3));
    }

    #[test]
    fn clear_owner_if_is_tier_agnostic() {
        let dir = CacheDirectory::new(4);
        dir.set_owner_tier(0, 2, Tier::Disk);
        assert!(!dir.clear_owner_if(0, 1), "wrong owner must not clear");
        assert!(dir.clear_owner_if(0, 2), "disk-tier entry must clear");
        assert_eq!(dir.owner(0), None);
        assert_eq!(dir.cached_samples(), 0);
    }

    #[test]
    fn evict_owner_clears_only_that_owners_claims() {
        let dir = CacheDirectory::striped(100, 4);
        // A disk-tier claim is swept just the same.
        dir.set_owner_tier(1, 1, Tier::Disk);
        assert_eq!(dir.evict_owner(1), 25);
        assert_eq!(dir.cached_samples(), 75);
        let counts = dir.counts(4);
        assert_eq!(counts[1], 0);
        assert_eq!(counts[0] + counts[2] + counts[3], 75);
        assert_eq!(dir.tier_counts(), (75, 0));
        // Idempotent: a second sweep finds nothing.
        assert_eq!(dir.evict_owner(1), 0);
    }

    #[test]
    fn concurrent_eviction_and_reclaim_leaves_no_stale_claims() {
        use std::sync::Arc;
        // A dead owner's sweep racing live learners re-claiming half of
        // its ids: no surviving entry names the dead owner, the other
        // half ends cleared, and cached/tier counters agree with a full
        // rescan (the CAS protocol never double-counts).
        let n = 4096u32;
        for _ in 0..4 {
            let dir = Arc::new(CacheDirectory::striped(n as u64, 4));
            let mut handles = Vec::new();
            {
                let dir = Arc::clone(&dir);
                handles.push(std::thread::spawn(move || dir.evict_owner(0)));
            }
            // Learners 1-3 re-claim the dead owner's ids with s % 8 == 0
            // (a third each, mixed tiers); ids with s % 8 == 4 stay his.
            for t in 1..4usize {
                let dir = Arc::clone(&dir);
                handles.push(std::thread::spawn(move || {
                    let mut claimed = 0u64;
                    for s in (0..n).step_by(8) {
                        if (s / 8) as usize % 3 + 1 != t {
                            continue;
                        }
                        let tier =
                            if s % 16 == 0 { Tier::Mem } else { Tier::Disk };
                        dir.set_owner_tier(s, t, tier);
                        claimed += 1;
                    }
                    claimed
                }));
            }
            let cleared = handles.remove(0).join().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            assert!((512..=1024).contains(&cleared), "cleared {cleared}");
            let (mut mem, mut disk, mut cached) = (0u64, 0u64, 0u64);
            for s in 0..n {
                if let Some((o, tier)) = dir.owner_tier(s) {
                    assert_ne!(o, 0, "stale claim for dead owner at {s}");
                    cached += 1;
                    match tier {
                        Tier::Mem => mem += 1,
                        Tier::Disk => disk += 1,
                    }
                }
            }
            assert_eq!(cached, (n - n / 8) as u64);
            assert_eq!(dir.cached_samples(), cached);
            assert_eq!(dir.tier_counts(), (mem, disk));
        }
    }

    #[test]
    fn clone_is_a_snapshot() {
        let dir = CacheDirectory::striped(16, 4);
        let snap = dir.clone();
        dir.set_owner(0, 3);
        assert_eq!(snap.owner(0), Some(0));
        assert_eq!(snap.cached_samples(), 16);
    }

    #[test]
    fn raw_snapshot_round_trips_owners_tiers_and_counts() {
        let dir = CacheDirectory::striped(64, 4);
        dir.set_owner_tier(5, 2, Tier::Disk);
        dir.clear_owner_if(6, 2);
        let words = dir.snapshot_raw();
        assert_eq!(words.len(), 64);

        let rebuilt = CacheDirectory::from_raw(&words);
        assert_eq!(rebuilt.cached_samples(), dir.cached_samples());
        assert_eq!(rebuilt.tier_counts(), dir.tier_counts());
        for s in 0..64u32 {
            assert_eq!(rebuilt.owner_tier(s), dir.owner_tier(s));
        }

        // In-place restore over a diverged directory converges too.
        let live = CacheDirectory::new(64);
        live.set_owner(0, 3);
        live.restore_raw(&words);
        assert_eq!(live.cached_samples(), dir.cached_samples());
        assert_eq!(live.owner_tier(5), Some((2, Tier::Disk)));
        assert_eq!(live.owner(6), None);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn restore_raw_rejects_wrong_length() {
        let dir = CacheDirectory::new(8);
        dir.restore_raw(&[0u32; 4]);
    }

    #[test]
    fn prop_population_layouts_agree_on_counts() {
        prop::check("directory layouts", 100, |rng| {
            let n = 1 + rng.next_below(10_000);
            let p = 1 + rng.next_below(32) as usize;
            let block = CacheDirectory::block_populated(n, p);
            let striped = CacheDirectory::striped(n, p);
            // Same multiset of per-learner counts: both are even splits.
            let mut a = block.counts(p);
            let mut b = striped.counts(p);
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
            assert_eq!(block.cached_samples(), n);
            assert_eq!(striped.cached_samples(), n);
        });
    }
}
