//! The replicated cache directory (paper §V-A).
//!
//! Tracks, for every sample id, which learner's cache holds it. The paper
//! assumes "a cache directory exists for tracking sample locations, and the
//! directory is duplicated across all learners and stays the same (i.e. no
//! cache replacement) after populating caches in the first epoch" — so the
//! directory here is a plain dense vector, cheap to replicate and to
//! consult once per sample per step.

/// Sentinel for "not cached anywhere".
const NONE: u32 = u32::MAX;

/// Dense sample-id -> owning-learner map.
#[derive(Clone, Debug)]
pub struct CacheDirectory {
    owner: Vec<u32>,
    cached: u64,
}

impl CacheDirectory {
    pub fn new(n_samples: u64) -> Self {
        CacheDirectory { owner: vec![NONE; n_samples as usize], cached: 0 }
    }

    pub fn n_samples(&self) -> u64 {
        self.owner.len() as u64
    }

    /// Which learner caches `sample`, if any.
    #[inline]
    pub fn owner(&self, sample: u32) -> Option<usize> {
        match self.owner.get(sample as usize) {
            Some(&o) if o != NONE => Some(o as usize),
            _ => None,
        }
    }

    /// Record that `learner` caches `sample`. Idempotent; re-assignment is
    /// a logic error under the paper's no-replacement policy (but tolerated
    /// as last-writer-wins to keep population code simple).
    pub fn set_owner(&mut self, sample: u32, learner: usize) {
        let slot = &mut self.owner[sample as usize];
        if *slot == NONE {
            self.cached += 1;
        }
        *slot = learner as u32;
    }

    /// Number of samples cached somewhere.
    pub fn cached_samples(&self) -> u64 {
        self.cached
    }

    /// The paper's α: fraction of the dataset in the aggregated cache.
    pub fn alpha(&self) -> f64 {
        self.cached as f64 / self.owner.len().max(1) as f64
    }

    /// Build a directory where learner `j` owns the contiguous block
    /// `[j*n/p, (j+1)*n/p)` — the "easily determined sample locations"
    /// population the paper recommends to avoid extra bookkeeping.
    pub fn block_populated(n_samples: u64, p: usize) -> Self {
        let mut dir = CacheDirectory::new(n_samples);
        let base = n_samples / p as u64;
        let rem = n_samples % p as u64;
        let mut cursor = 0u64;
        for j in 0..p {
            let take = base + u64::from((j as u64) < rem);
            for s in cursor..cursor + take {
                dir.set_owner(s as u32, j);
            }
            cursor += take;
        }
        dir
    }

    /// Build a directory where ownership is striped (`sample % p`). Both
    /// layouts are valid ("how samples are cached is not important, since
    /// the mini-batch sequences are randomly shuffled"); striping spreads
    /// shard-local I/O during population.
    pub fn striped(n_samples: u64, p: usize) -> Self {
        let mut dir = CacheDirectory::new(n_samples);
        for s in 0..n_samples {
            dir.set_owner(s as u32, (s % p as u64) as usize);
        }
        dir
    }

    /// Per-learner cached-sample counts.
    pub fn counts(&self, p: usize) -> Vec<u64> {
        let mut counts = vec![0u64; p];
        for &o in &self.owner {
            if o != NONE {
                counts[o as usize] += 1;
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn empty_directory_has_no_owners() {
        let dir = CacheDirectory::new(100);
        assert_eq!(dir.owner(0), None);
        assert_eq!(dir.owner(99), None);
        assert_eq!(dir.cached_samples(), 0);
        assert_eq!(dir.alpha(), 0.0);
    }

    #[test]
    fn set_and_lookup() {
        let mut dir = CacheDirectory::new(10);
        dir.set_owner(3, 2);
        dir.set_owner(7, 0);
        assert_eq!(dir.owner(3), Some(2));
        assert_eq!(dir.owner(7), Some(0));
        assert_eq!(dir.owner(4), None);
        assert_eq!(dir.cached_samples(), 2);
        // Re-setting doesn't double count.
        dir.set_owner(3, 1);
        assert_eq!(dir.cached_samples(), 2);
        assert_eq!(dir.owner(3), Some(1));
    }

    #[test]
    fn block_population_is_disjoint_and_complete() {
        let dir = CacheDirectory::block_populated(103, 4);
        assert_eq!(dir.alpha(), 1.0);
        let counts = dir.counts(4);
        assert_eq!(counts, vec![26, 26, 26, 25]);
        // Block property: owners are non-decreasing.
        let mut last = 0;
        for s in 0..103u32 {
            let o = dir.owner(s).unwrap();
            assert!(o >= last);
            last = o;
        }
    }

    #[test]
    fn striped_population_counts() {
        let dir = CacheDirectory::striped(10, 3);
        assert_eq!(dir.counts(3), vec![4, 3, 3]);
        assert_eq!(dir.owner(4), Some(1));
    }

    #[test]
    fn prop_population_layouts_agree_on_counts() {
        prop::check("directory layouts", 100, |rng| {
            let n = 1 + rng.next_below(10_000);
            let p = 1 + rng.next_below(32) as usize;
            let block = CacheDirectory::block_populated(n, p);
            let striped = CacheDirectory::striped(n, p);
            // Same multiset of per-learner counts: both are even splits.
            let mut a = block.counts(p);
            let mut b = striped.counts(p);
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
            assert_eq!(block.cached_samples(), n);
            assert_eq!(striped.cached_samples(), n);
        });
    }
}
