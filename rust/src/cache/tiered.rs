//! Hierarchical (memory + SSD) sample cache — the paper's stated future
//! work (§VIII: "explore using SSD which provides ample space and fast
//! access, and is ideal for a hierarchical caching design") and the §III-C
//! observation that "training datasets too large to fit in the local DRAM
//! can be cached in SSDs".
//!
//! Two tiers, both insert-only (no replacement, per the paper's model):
//!
//! * **mem** — a [`SampleCache`] (byte-capacity-bounded, *sharded* — the
//!   fast path shares the sharded-lock + atomic-accounting rewrite
//!   instead of duplicating its own single-mutex map);
//! * **disk** — an append-only spill file with an in-memory index; reads
//!   go through `read_at` and an optional simulated device latency, so the
//!   DRAM-vs-SSD hierarchy of the paper is measurable in the live
//!   pipeline.
//!
//! Thread-safe like [`SampleCache`]; the loader can use either tier
//! transparently via [`TieredCache::get`].

use super::sample_cache::{Policy, SampleCache};
use crate::storage::Sample;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Clone, Copy)]
struct DiskSlot {
    offset: u64,
    len: u32,
    label: u16,
}

struct DiskTier {
    index: HashMap<u32, DiskSlot>,
    file: File,
    cursor: u64,
}

/// Two-tier DRAM + SSD cache.
pub struct TieredCache {
    mem: SampleCache,
    disk: Mutex<DiskTier>,
    disk_capacity: u64,
    /// Simulated device read latency per disk hit (0 for a real SSD).
    disk_latency: Duration,
    path: PathBuf,
    disk_hits: AtomicU64,
    misses: AtomicU64,
}

impl TieredCache {
    /// Create a tiered cache spilling to `spill_path` (truncated).
    pub fn create(
        spill_path: impl AsRef<Path>,
        mem_capacity: u64,
        disk_capacity: u64,
        disk_latency: Duration,
    ) -> Result<Self> {
        let path = spill_path.as_ref().to_path_buf();
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create spill file {}", path.display()))?;
        Ok(TieredCache {
            mem: SampleCache::new(mem_capacity, Policy::InsertOnly),
            disk: Mutex::new(DiskTier {
                index: HashMap::new(),
                file,
                cursor: 0,
            }),
            disk_capacity,
            disk_latency,
            path,
            disk_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// Insert a sample: memory first, spill to disk when memory is full.
    /// Returns `false` only when *both* tiers are at capacity.
    pub fn insert(&self, sample: std::sync::Arc<Sample>) -> Result<bool> {
        let sz = sample.size() as u64;
        // Sharded mem tier: idempotent on duplicates, rejects when full.
        if self.mem.insert(std::sync::Arc::clone(&sample)) {
            return Ok(true);
        }
        // Spill to the disk tier.
        let mut disk = self.disk.lock().unwrap();
        if disk.index.contains_key(&sample.id) {
            return Ok(true);
        }
        if disk.cursor + sz > self.disk_capacity {
            return Ok(false);
        }
        let offset = disk.cursor;
        disk.file.write_all(&sample.bytes)?;
        disk.cursor += sz;
        disk.index.insert(
            sample.id,
            DiskSlot { offset, len: sample.bytes.len() as u32, label: sample.label },
        );
        Ok(true)
    }

    /// Look up a sample in either tier.
    pub fn get(&self, id: u32) -> Result<Option<std::sync::Arc<Sample>>> {
        if let Some(s) = self.mem.get(id) {
            return Ok(Some(s));
        }
        let slot = {
            let disk = self.disk.lock().unwrap();
            disk.index.get(&id).copied()
        };
        match slot {
            Some(slot) => {
                if !self.disk_latency.is_zero() {
                    std::thread::sleep(self.disk_latency);
                }
                let mut bytes = vec![0u8; slot.len as usize];
                // read_at needs no lock: writes only append past `offset`.
                self.disk
                    .lock()
                    .unwrap()
                    .file
                    .read_exact_at(&mut bytes, slot.offset)?;
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                Ok(Some(std::sync::Arc::new(Sample {
                    id,
                    bytes: bytes.into(),
                    label: slot.label,
                })))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    pub fn contains(&self, id: u32) -> bool {
        self.mem.contains(id)
            || self.disk.lock().unwrap().index.contains_key(&id)
    }

    pub fn mem_len(&self) -> usize {
        self.mem.len()
    }

    pub fn disk_len(&self) -> usize {
        self.disk.lock().unwrap().index.len()
    }

    pub fn mem_hits(&self) -> u64 {
        self.mem.hits()
    }

    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn spill_path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn sample(id: u32, size: usize) -> Arc<Sample> {
        Arc::new(Sample {
            id,
            bytes: vec![(id % 251) as u8; size].into(),
            label: id as u16,
        })
    }

    fn cache(mem: u64, disk: u64) -> TieredCache {
        let p = std::env::temp_dir().join(format!(
            "dlio-tier-{}-{:?}.spill",
            std::process::id(),
            std::thread::current().id()
        ));
        TieredCache::create(&p, mem, disk, Duration::ZERO).unwrap()
    }

    #[test]
    fn memory_first_then_spill() {
        let c = cache(250, 10_000);
        assert!(c.insert(sample(1, 100)).unwrap());
        assert!(c.insert(sample(2, 100)).unwrap());
        assert!(c.insert(sample(3, 100)).unwrap()); // spills
        assert_eq!(c.mem_len(), 2);
        assert_eq!(c.disk_len(), 1);
        // All three retrievable with correct bytes + labels.
        for id in 1..=3u32 {
            let s = c.get(id).unwrap().unwrap();
            assert_eq!(s.bytes, vec![(id % 251) as u8; 100]);
            assert_eq!(s.label, id as u16);
        }
        assert_eq!(c.mem_hits(), 2);
        assert_eq!(c.disk_hits(), 1);
    }

    #[test]
    fn both_tiers_full_rejects() {
        let c = cache(100, 150);
        assert!(c.insert(sample(1, 100)).unwrap()); // mem
        assert!(c.insert(sample(2, 100)).unwrap()); // disk
        assert!(!c.insert(sample(3, 100)).unwrap()); // both full
        assert!(!c.contains(3));
        assert_eq!(c.get(3).unwrap(), None);
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn duplicate_inserts_idempotent_across_tiers() {
        let c = cache(100, 10_000);
        assert!(c.insert(sample(1, 100)).unwrap());
        assert!(c.insert(sample(1, 100)).unwrap());
        assert!(c.insert(sample(2, 100)).unwrap()); // disk
        assert!(c.insert(sample(2, 100)).unwrap());
        assert_eq!(c.mem_len(), 1);
        assert_eq!(c.disk_len(), 1);
    }

    #[test]
    fn disk_latency_is_charged() {
        let p = std::env::temp_dir()
            .join(format!("dlio-tier-lat-{}.spill", std::process::id()));
        let c = TieredCache::create(&p, 0, 10_000, Duration::from_millis(5))
            .unwrap();
        c.insert(sample(9, 64)).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            c.get(9).unwrap().unwrap();
        }
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn concurrent_mixed_tier_access() {
        let c = Arc::new(cache(50 * 64, 100_000));
        for id in 0..100u32 {
            c.insert(sample(id, 64)).unwrap(); // 50 in mem, 50 on disk
        }
        assert_eq!(c.mem_len(), 50);
        assert_eq!(c.disk_len(), 50);
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for id in (t..100).step_by(4) {
                    let s = c.get(id as u32).unwrap().unwrap();
                    assert_eq!(s.bytes[0], (id % 251) as u8);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.mem_hits() + c.disk_hits(), 100);
    }
}
