//! Hierarchical cache stack (paper §III-C + §VIII): the DRAM tier backed
//! by a zero-copy SSD spill tier, behind ONE handle the whole fetch path
//! holds.
//!
//! The paper singles out SSDs as the way to keep the locality-aware
//! scheme's communication savings once per-node DRAM runs out ("training
//! datasets too large to fit in the local DRAM can be cached in SSDs",
//! §III-C; "ideal for a hierarchical caching design", §VIII). This module
//! promotes that hierarchy to a first-class subsystem:
//!
//! * **mem** — the sharded, atomically-accounted [`SampleCache`];
//! * **disk** — a [`DiskTier`]: a preallocated spill *segment* with a
//!   sharded in-memory index. Offsets are claimed by a lock-free cursor
//!   reservation (occupancy is accounted with the *written* length, so a
//!   size/len mismatch can never drift the cursor away from the bytes on
//!   disk), writes go through `pwrite`, and reads hand out **mmap-backed
//!   [`SampleBytes`] views** of the shared segment mapping — a disk hit
//!   copies zero payload bytes, preserving the one-copy invariant
//!   (DESIGN.md §2) for the SSD tier;
//! * **write-behind spill** — a mem-tier rejection *reserves* its slot
//!   inline (so admission stays exact) but performs the SSD write as a
//!   task on the attached persistent [`Executor`], keeping spill writes
//!   off the batch critical path. The caller's commit hook (directory
//!   claim) runs only after the bytes are durable and indexed.
//!
//! Both tiers are insert-only on the locality-aware path (no replacement
//! after population, per the paper's model); the mem tier may run Fifo for
//! the partial-cache ablations. Thread-safe throughout; the loader's
//! workers, the decode executor's tasks and remote peers all operate on
//! one `Arc<CacheStack>` per learner.
//!
//! [`SampleBytes`]: crate::storage::SampleBytes
//! [`Executor`]: crate::util::Executor

use super::sample_cache::{Policy, SampleCache};
use super::Tier;
use crate::metrics::TierSnapshot;
use crate::storage::bytes::Mmap;
use crate::storage::{Sample, SampleBytes};
use crate::util::Executor;
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Index shards of the disk tier (power of two; id-hashed like the mem
/// tier's shards, so concurrent spill commits and slot lookups only
/// serialize when they collide).
const DISK_SHARDS: usize = 16;

/// Spill-tier configuration.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Where the spill segment lives (created, truncated, preallocated to
    /// `capacity_bytes`; unlinked when the tier drops).
    pub path: PathBuf,
    /// Segment size — a real byte budget (the file is preallocated and
    /// mapped at this length), not a `u64::MAX`-style "unbounded".
    pub capacity_bytes: u64,
    /// Simulated device read latency per disk hit (0 for a real SSD).
    pub read_latency: Duration,
}

#[derive(Clone, Copy)]
struct DiskSlot {
    offset: u64,
    len: u32,
    label: u16,
}

/// The SSD spill tier: cursor-reserved segment + sharded index, reads are
/// mmap-backed views. See the module docs for the write-once/publish
/// protocol that keeps the shared mapping sound.
pub struct DiskTier {
    file: File,
    map: Arc<Mmap>,
    capacity: u64,
    /// Reserved bytes (monotone). Reservation happens at admission time on
    /// the caller's thread so capacity accounting is exact even while the
    /// write itself runs behind.
    cursor: AtomicU64,
    shards: Box<[Mutex<HashMap<u32, DiskSlot>>]>,
    entries: AtomicU64,
    committed_bytes: AtomicU64,
    read_latency: Duration,
    path: PathBuf,
}

impl DiskTier {
    fn create(cfg: &SpillConfig) -> Result<DiskTier> {
        ensure!(
            cfg.capacity_bytes > 0,
            "disk tier needs a positive capacity"
        );
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&cfg.path)
            .with_context(|| {
                format!("create spill segment {}", cfg.path.display())
            })?;
        // Preallocate (sparse) so the whole segment can be mapped once;
        // slots become readable through the shared mapping as they are
        // written and indexed.
        file.set_len(cfg.capacity_bytes).with_context(|| {
            format!(
                "preallocate {} bytes of spill segment (disk capacity must \
                 be a real byte budget)",
                cfg.capacity_bytes
            )
        })?;
        let map = Arc::new(Mmap::map_shared(&file).with_context(|| {
            format!("map spill segment {}", cfg.path.display())
        })?);
        Ok(DiskTier {
            file,
            map,
            capacity: cfg.capacity_bytes,
            cursor: AtomicU64::new(0),
            shards: (0..DISK_SHARDS)
                .map(|_| Mutex::new(HashMap::new()))
                .collect(),
            entries: AtomicU64::new(0),
            committed_bytes: AtomicU64::new(0),
            read_latency: cfg.read_latency,
            path: cfg.path.clone(),
        })
    }

    fn shard_index(&self, id: u32) -> usize {
        let h = (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        (h as usize) & (DISK_SHARDS - 1)
    }

    fn slot(&self, id: u32) -> Option<DiskSlot> {
        self.shards[self.shard_index(id)]
            .lock()
            .unwrap()
            .get(&id)
            .copied()
    }

    fn contains(&self, id: u32) -> bool {
        self.slot(id).is_some()
    }

    /// Claim `len` bytes of the segment; `None` when the tier is full.
    fn reserve(&self, len: u64) -> Option<u64> {
        let cap = self.capacity;
        self.cursor
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| {
                match c.checked_add(len) {
                    Some(nc) if nc <= cap => Some(nc),
                    _ => None,
                }
            })
            .ok()
    }

    /// Write the payload at its reserved offset, then publish the index
    /// entry. The write happens strictly before the publish (same thread),
    /// so a reader that finds the slot only ever sees final bytes through
    /// the shared mapping. Returns `false` if a racing insert of the same
    /// id published first (this reservation's span is then simply unused).
    fn commit(&self, offset: u64, sample: &Sample) -> std::io::Result<bool> {
        let len = sample.bytes.len();
        self.file.write_all_at(&sample.bytes, offset)?;
        {
            let mut shard =
                self.shards[self.shard_index(sample.id)].lock().unwrap();
            if shard.contains_key(&sample.id) {
                return Ok(false);
            }
            shard.insert(
                sample.id,
                DiskSlot {
                    offset,
                    len: len as u32,
                    label: sample.label,
                },
            );
        }
        self.entries.fetch_add(1, Ordering::Relaxed);
        // Occupancy accounted with the WRITTEN length — the same quantity
        // the reservation claimed — so cursor and on-disk bytes can never
        // drift apart (the old append-file tier advanced its cursor by a
        // separately computed size).
        self.committed_bytes.fetch_add(len as u64, Ordering::Relaxed);
        Ok(true)
    }

    /// One latency charge + one mmap-backed view; zero payload copies and
    /// no second index lock (the slot was copied out by the caller).
    fn read(&self, id: u32, slot: DiskSlot) -> Arc<Sample> {
        if !self.read_latency.is_zero() {
            std::thread::sleep(self.read_latency);
        }
        let bytes = SampleBytes::from_map(
            Arc::clone(&self.map),
            slot.offset as usize,
            slot.len as usize,
        );
        Arc::new(Sample { id, bytes, label: slot.label })
    }

    /// Drop every slot and rewind the reservation cursor so the segment
    /// can be refilled. UNSAFE TO CALL with disk-hit views outstanding —
    /// new writes would land under their mapped spans; the rejoin path
    /// only clears after the node's loader has shut down.
    fn clear(&self) {
        for shard in self.shards.iter() {
            shard.lock().unwrap().clear();
        }
        self.entries.store(0, Ordering::Relaxed);
        self.committed_bytes.store(0, Ordering::Relaxed);
        self.cursor.store(0, Ordering::Relaxed);
    }

    pub fn entries(&self) -> u64 {
        self.entries.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.committed_bytes.load(Ordering::Relaxed)
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for DiskTier {
    fn drop(&mut self) {
        // Unlink the segment: live mappings stay valid until munmap, and
        // unit-test runs stop littering temp_dir with `.spill` files.
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Outcome of a [`CacheStack`] admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Resident in the DRAM tier (or already was).
    Mem,
    /// Reserved on the disk tier; the SSD write (and the caller's commit
    /// hook) runs write-behind on the spill executor.
    SpillQueued,
    /// Resident in the disk tier (inline spill, or already there).
    Disk,
    /// Every tier is at capacity (or the write failed inline).
    Rejected,
}

/// Result of the routing probe [`CacheStack::lookup`].
pub enum Lookup {
    /// DRAM hit — the zero-copy `Arc` handout, resolved inline.
    Mem(Arc<Sample>),
    /// Resident in the disk tier; resolve with [`CacheStack::get_disk`]
    /// (the fetch path defers this into the overlapped task wave so the
    /// SSD read, and any simulated device latency, runs under in-flight
    /// transfers).
    Disk,
    /// In neither tier.
    Miss,
}

/// Hook invoked once an admitted sample is actually resident (mem: inline;
/// write-behind spill: on the executor, after the write + index publish).
/// The argument is the tier that holds it — the fetch path uses this to
/// publish tier-accurate directory claims.
pub type CommitHook = Box<dyn FnOnce(Tier) + Send + 'static>;

#[derive(Default)]
struct SpillStats {
    pending: AtomicU64,
    queue_peak: AtomicU64,
    offpath: AtomicU64,
    inline: AtomicU64,
    failures: AtomicU64,
    bytes: AtomicU64,
}

/// The unified mem + disk cache handle (see module docs).
pub struct CacheStack {
    mem: SampleCache,
    disk: Option<Arc<DiskTier>>,
    spill_executor: Option<Arc<Executor>>,
    spill: Arc<SpillStats>,
    disk_hits: AtomicU64,
    disk_hit_bytes: AtomicU64,
    /// Nonzero means a disk hit handed out a non-mapped payload — the
    /// zero-copy invariant broke; benches/CI assert this stays 0.
    disk_hit_copied_bytes: AtomicU64,
    misses: AtomicU64,
    rejected: AtomicU64,
}

impl CacheStack {
    /// A DRAM-only stack — exactly the pre-hierarchy [`SampleCache`]
    /// behaviour behind the stack handle.
    pub fn mem_only(capacity_bytes: u64, policy: Policy) -> CacheStack {
        CacheStack {
            mem: SampleCache::new(capacity_bytes, policy),
            disk: None,
            spill_executor: None,
            spill: Arc::new(SpillStats::default()),
            disk_hits: AtomicU64::new(0),
            disk_hit_bytes: AtomicU64::new(0),
            disk_hit_copied_bytes: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// A two-tier stack spilling mem-tier rejections into `spill`'s
    /// segment. Spills run inline until a spill executor is attached with
    /// [`with_spill_executor`].
    ///
    /// [`with_spill_executor`]: CacheStack::with_spill_executor
    pub fn tiered(
        mem_capacity_bytes: u64,
        policy: Policy,
        spill: &SpillConfig,
    ) -> Result<CacheStack> {
        let mut stack = CacheStack::mem_only(mem_capacity_bytes, policy);
        stack.disk = Some(Arc::new(DiskTier::create(spill)?));
        Ok(stack)
    }

    /// Attach the persistent executor that runs write-behind spills. SSD
    /// writes then leave the batch critical path entirely: admission only
    /// reserves the slot and enqueues the write.
    pub fn with_spill_executor(mut self, ex: Arc<Executor>) -> CacheStack {
        self.spill_executor = Some(ex);
        self
    }

    /// The DRAM tier (shard stats, capacity, residency).
    pub fn mem(&self) -> &SampleCache {
        &self.mem
    }

    /// The SSD tier, when configured.
    pub fn disk(&self) -> Option<&DiskTier> {
        self.disk.as_deref()
    }

    pub fn has_disk_tier(&self) -> bool {
        self.disk.is_some()
    }

    /// Insert a sample, memory first, spilling to disk when memory is
    /// full. `false` only when every tier rejected it.
    pub fn insert(&self, sample: Arc<Sample>) -> bool {
        !matches!(self.insert_with(sample, None), Admit::Rejected)
    }

    /// As [`insert`], running `on_commit` with the holding tier once the
    /// sample is resident — inline for mem admissions and duplicates,
    /// after the SSD write + index publish for write-behind spills (where
    /// it is how the fetch path defers its directory claim until the
    /// bytes are actually servable). A rejected insert drops the hook
    /// unrun.
    ///
    /// [`insert`]: CacheStack::insert
    pub fn insert_with(
        &self,
        sample: Arc<Sample>,
        on_commit: Option<CommitHook>,
    ) -> Admit {
        if self.mem.insert(Arc::clone(&sample)) {
            if let Some(hook) = on_commit {
                hook(Tier::Mem);
            }
            return Admit::Mem;
        }
        let Some(disk) = &self.disk else {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Admit::Rejected;
        };
        if disk.contains(sample.id) {
            if let Some(hook) = on_commit {
                hook(Tier::Disk);
            }
            return Admit::Disk;
        }
        let len = sample.bytes.len() as u64;
        let Some(offset) = disk.reserve(len) else {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Admit::Rejected;
        };
        match &self.spill_executor {
            Some(ex) => {
                let disk = Arc::clone(disk);
                let stats = Arc::clone(&self.spill);
                let depth = stats.pending.fetch_add(1, Ordering::Relaxed) + 1;
                stats.queue_peak.fetch_max(depth, Ordering::Relaxed);
                ex.submit(move || {
                    match disk.commit(offset, &sample) {
                        Ok(true) => {
                            stats.offpath.fetch_add(1, Ordering::Relaxed);
                            stats.bytes.fetch_add(len, Ordering::Relaxed);
                            if let Some(hook) = on_commit {
                                hook(Tier::Disk);
                            }
                        }
                        // A racing insert of the same id won the publish;
                        // its commit ran the claim.
                        Ok(false) => {}
                        Err(_) => {
                            stats.failures.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    stats.pending.fetch_sub(1, Ordering::Relaxed);
                });
                Admit::SpillQueued
            }
            None => match disk.commit(offset, &sample) {
                Ok(committed) => {
                    if committed {
                        self.spill.inline.fetch_add(1, Ordering::Relaxed);
                        self.spill.bytes.fetch_add(len, Ordering::Relaxed);
                    }
                    if let Some(hook) = on_commit {
                        hook(Tier::Disk);
                    }
                    Admit::Disk
                }
                Err(_) => {
                    self.spill.failures.fetch_add(1, Ordering::Relaxed);
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    Admit::Rejected
                }
            },
        }
    }

    /// Routing probe: resolve a DRAM hit inline, *identify* a disk-tier
    /// resident without reading it, or miss. Every call ticks exactly one
    /// of {mem hit, disk hit, miss}, so
    /// `mem_hits + disk_hits + misses == lookups` holds at all times.
    pub fn lookup(&self, id: u32) -> Lookup {
        if let Some(s) = self.mem.get(id) {
            return Lookup::Mem(s);
        }
        if let Some(disk) = &self.disk {
            if disk.contains(id) {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                return Lookup::Disk;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Lookup::Miss
    }

    /// Resolve a disk-tier resident: one latency charge, one mmap-backed
    /// view, zero payload copies. Pairs with a [`lookup`] that returned
    /// [`Lookup::Disk`] (the hit was counted there). `None` only if the
    /// slot vanished, which insert-only tiers never do.
    ///
    /// [`lookup`]: CacheStack::lookup
    pub fn get_disk(&self, id: u32) -> Option<Arc<Sample>> {
        let disk = self.disk.as_ref()?;
        let slot = disk.slot(id)?;
        let s = disk.read(id, slot);
        self.disk_hit_bytes
            .fetch_add(slot.len as u64, Ordering::Relaxed);
        if !s.bytes.is_zero_copy() {
            self.disk_hit_copied_bytes
                .fetch_add(slot.len as u64, Ordering::Relaxed);
        }
        Some(s)
    }

    /// Look up a sample in either tier.
    pub fn get(&self, id: u32) -> Option<Arc<Sample>> {
        match self.lookup(id) {
            Lookup::Mem(s) => Some(s),
            Lookup::Disk => self.get_disk(id),
            Lookup::Miss => None,
        }
    }

    /// As [`get`], reporting which tier served the hit (tier-accurate
    /// directory repair).
    ///
    /// [`get`]: CacheStack::get
    pub fn get_tiered(&self, id: u32) -> Option<(Tier, Arc<Sample>)> {
        match self.lookup(id) {
            Lookup::Mem(s) => Some((Tier::Mem, s)),
            Lookup::Disk => self.get_disk(id).map(|s| (Tier::Disk, s)),
            Lookup::Miss => None,
        }
    }

    /// Peek without touching hit/miss counters.
    pub fn contains(&self, id: u32) -> bool {
        self.mem.contains(id)
            || self.disk.as_ref().is_some_and(|d| d.contains(id))
    }

    /// Write-behind spills not yet committed.
    pub fn spill_queue_depth(&self) -> u64 {
        self.spill.pending.load(Ordering::Relaxed)
    }

    /// Block until every queued spill has committed. Used at
    /// population/epoch boundaries and before snapshots. Liveness holds by
    /// construction: the stack keeps its spill executor alive (`Arc`), the
    /// executor drains its queue before shutting down, and a failed write
    /// still decrements the pending gauge — so this terminates however
    /// slow the device or deep the backlog.
    pub fn drain_spills(&self) {
        while self.spill.pending.load(Ordering::Relaxed) > 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Empty both tiers — the cold-cache rejoin (DESIGN.md §12): a node
    /// revived after a death window must not serve payloads cached before
    /// it died (its directory claims were swept at detection, so nothing
    /// routes to them; the data itself is re-fetched on demand). Queued
    /// spills are drained first so no write-behind commit resurrects an
    /// entry after the wipe. Lifetime hit/miss/spill counters are kept.
    /// Callers must ensure the node's loader is shut down (no outstanding
    /// disk-hit views) before clearing a disk-tiered stack.
    pub fn clear(&self) {
        self.drain_spills();
        self.mem.clear();
        if let Some(d) = &self.disk {
            d.clear();
        }
    }

    /// Tier accounting for `BENCH_hotpath.json` / `TrainingReport.tiers`.
    pub fn tier_snapshot(&self) -> TierSnapshot {
        let (disk_entries, disk_bytes, disk_capacity) = match &self.disk {
            Some(d) => (d.entries(), d.bytes(), d.capacity_bytes()),
            None => (0, 0, 0),
        };
        TierSnapshot {
            mem_hits: self.mem.hits(),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            mem_entries: self.mem.len() as u64,
            mem_bytes: self.mem.bytes(),
            mem_capacity: self.mem.capacity_bytes(),
            disk_entries,
            disk_bytes,
            disk_capacity,
            spill_bytes: self.spill.bytes.load(Ordering::Relaxed),
            spill_queue_depth: self.spill.pending.load(Ordering::Relaxed),
            spill_queue_peak: self.spill.queue_peak.load(Ordering::Relaxed),
            spilled_offpath: self.spill.offpath.load(Ordering::Relaxed),
            spilled_inline: self.spill.inline.load(Ordering::Relaxed),
            spill_failures: self.spill.failures.load(Ordering::Relaxed),
            disk_hit_bytes: self.disk_hit_bytes.load(Ordering::Relaxed),
            disk_hit_copied_bytes: self
                .disk_hit_copied_bytes
                .load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
        }
    }
}

/// Startup sweep (DESIGN.md §13): remove spill segments orphaned by dead
/// processes.
///
/// [`DiskTier`] unlinks its segment on drop, but a SIGKILLed process —
/// exactly what the multi-process supervisor injects — never runs `Drop`,
/// so its segment leaks in `spill_dir` forever. This sweep runs at job
/// startup, before any new tier is created: it scans `dir` for files
/// matching the crate's spill naming schemes (`dlio-spill-{pid}-…` /
/// `dlio-stack-…-{pid}-….spill`), parses the owning pid out of the name,
/// and removes the file only when that process no longer exists. Files
/// owned by live processes (including our own) and files that don't
/// match the naming scheme are never touched. Returns the number of
/// segments removed; all I/O errors are swallowed (a sweep must never
/// block a job from starting).
pub fn sweep_orphaned_spills(dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    let mut removed = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(pid) = spill_owner_pid(name) else { continue };
        if pid == std::process::id() || process_exists(pid) {
            continue;
        }
        if std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Parse the owning pid out of a spill-segment file name, or `None` when
/// the name doesn't match a known scheme.
fn spill_owner_pid(name: &str) -> Option<u32> {
    if let Some(rest) = name.strip_prefix("dlio-spill-") {
        // Trainer scheme: dlio-spill-{pid}-{job}-l{j}.seg
        if !name.ends_with(".seg") {
            return None;
        }
        return rest.split('-').next()?.parse().ok();
    }
    if name.starts_with("dlio-stack-") && name.ends_with(".spill") {
        // Test scheme: dlio-stack-{tag}-{pid}-{thread}.spill — the pid
        // is the second-to-last dash-separated segment (tags may
        // themselves contain dashes).
        let stem = name.strip_suffix(".spill")?;
        let mut parts: Vec<&str> = stem.split('-').collect();
        parts.pop()?; // thread id
        return parts.pop()?.parse().ok();
    }
    None
}

/// Liveness check for the sweep. On Linux `/proc/{pid}` is authoritative;
/// elsewhere we can't check cheaply, so the sweep conservatively treats
/// every pid as alive (leak beats deleting a live process's segment).
fn process_exists(pid: u32) -> bool {
    #[cfg(target_os = "linux")]
    {
        std::path::Path::new(&format!("/proc/{pid}")).exists()
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(id: u32, size: usize) -> Arc<Sample> {
        Arc::new(Sample {
            id,
            bytes: vec![(id % 251) as u8; size].into(),
            label: id as u16,
        })
    }

    fn spill_cfg(tag: &str, capacity: u64, latency: Duration) -> SpillConfig {
        SpillConfig {
            path: std::env::temp_dir().join(format!(
                "dlio-stack-{tag}-{}-{:?}.spill",
                std::process::id(),
                std::thread::current().id()
            )),
            capacity_bytes: capacity,
            read_latency: latency,
        }
    }

    fn stack(tag: &str, mem: u64, disk: u64) -> CacheStack {
        CacheStack::tiered(
            mem,
            Policy::InsertOnly,
            &spill_cfg(tag, disk, Duration::ZERO),
        )
        .unwrap()
    }

    #[test]
    fn memory_first_then_spill_and_reads_are_zero_copy() {
        let c = stack("basic", 250, 10_000);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(c.insert(sample(3, 100))); // spills (inline: no executor)
        assert_eq!(c.mem().len(), 2);
        assert_eq!(c.disk().unwrap().entries(), 1);
        for id in 1..=3u32 {
            let s = c.get(id).unwrap();
            assert_eq!(s.bytes, vec![(id % 251) as u8; 100]);
            assert_eq!(s.label, id as u16);
        }
        let ts = c.tier_snapshot();
        assert_eq!(ts.mem_hits, 2);
        assert_eq!(ts.disk_hits, 1);
        assert_eq!(ts.misses, 0);
        assert_eq!(ts.spilled_inline, 1);
        assert_eq!(ts.spilled_offpath, 0);
        // The disk hit is an mmap view of the segment: zero payload copies.
        assert!(c.get(3).unwrap().bytes.is_zero_copy());
        assert_eq!(c.tier_snapshot().disk_hit_copied_bytes, 0);
    }

    #[test]
    fn clear_empties_both_tiers_and_allows_refill() {
        let c = stack("clear", 250, 10_000);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(c.insert(sample(3, 100))); // spills
        c.clear();
        assert_eq!(c.mem().len(), 0);
        assert_eq!(c.mem().bytes(), 0);
        assert_eq!(c.disk().unwrap().entries(), 0);
        assert_eq!(c.disk().unwrap().bytes(), 0);
        for id in 1..=3u32 {
            assert!(!c.contains(id), "cold cache still held {id}");
        }
        // The segment cursor rewound: a fresh fill fits and reads back.
        assert!(c.insert(sample(7, 200)));
        assert!(c.insert(sample(8, 200)));
        assert!(c.insert(sample(9, 200)));
        assert_eq!(c.get(9).unwrap().bytes, vec![(9 % 251) as u8; 200]);
        // Lifetime spill accounting survives the wipe.
        assert!(c.tier_snapshot().spilled_inline >= 1);
    }

    #[test]
    fn both_tiers_full_rejects() {
        let c = stack("full", 100, 150);
        assert!(c.insert(sample(1, 100))); // mem
        assert!(c.insert(sample(2, 100))); // disk
        assert!(!c.insert(sample(3, 100))); // both full
        assert!(!c.contains(3));
        assert!(c.get(3).is_none());
        let ts = c.tier_snapshot();
        assert_eq!(ts.misses, 1);
        assert_eq!(ts.rejected, 1);
    }

    #[test]
    fn duplicate_inserts_idempotent_across_tiers() {
        let c = stack("dup", 100, 10_000);
        assert_eq!(c.insert_with(sample(1, 100), None), Admit::Mem);
        assert_eq!(c.insert_with(sample(1, 100), None), Admit::Mem);
        assert_eq!(c.insert_with(sample(2, 100), None), Admit::Disk);
        assert_eq!(c.insert_with(sample(2, 100), None), Admit::Disk);
        assert_eq!(c.mem().len(), 1);
        assert_eq!(c.disk().unwrap().entries(), 1);
        // The duplicate disk insert neither re-wrote nor re-accounted.
        assert_eq!(c.disk().unwrap().bytes(), 100);
    }

    #[test]
    fn disk_offset_accounting_with_varied_sizes() {
        // Regression for the old tier's offset drift: occupancy must be
        // the sum of the WRITTEN lengths, every slot bit-identical —
        // varied sizes would have corrupted later offsets had reservation
        // and write disagreed.
        let c = stack("sizes", 0, 100_000);
        let sizes = [37usize, 1, 512, 64, 300, 7, 2048, 99];
        let mut total = 0u64;
        for (id, &sz) in sizes.iter().enumerate() {
            assert!(c.insert(sample(id as u32, sz)));
            total += sz as u64;
        }
        assert_eq!(c.disk().unwrap().bytes(), total);
        assert_eq!(c.disk().unwrap().entries(), sizes.len() as u64);
        for (id, &sz) in sizes.iter().enumerate() {
            let s = c.get(id as u32).unwrap();
            assert_eq!(s.bytes.len(), sz, "slot {id} length drifted");
            assert_eq!(
                s.bytes,
                vec![(id as u32 % 251) as u8; sz],
                "slot {id} bytes corrupted"
            );
            assert!(s.bytes.is_zero_copy());
        }
    }

    #[test]
    fn disk_latency_is_charged_once_per_hit() {
        let c = CacheStack::tiered(
            0,
            Policy::InsertOnly,
            &spill_cfg("lat", 10_000, Duration::from_millis(5)),
        )
        .unwrap();
        assert!(c.insert(sample(9, 64)));
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            c.get(9).unwrap();
        }
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn spill_segment_unlinked_on_drop() {
        let cfg = spill_cfg("drop", 4096, Duration::ZERO);
        let path = cfg.path.clone();
        {
            let c =
                CacheStack::tiered(0, Policy::InsertOnly, &cfg).unwrap();
            assert!(c.insert(sample(1, 64)));
            assert!(path.exists());
            // A view taken before the drop stays readable (mapping
            // outlives the unlink).
            let s = c.get(1).unwrap();
            drop(c);
            assert_eq!(s.bytes, vec![1u8; 64]);
        }
        assert!(!path.exists(), "spill segment must be unlinked on drop");
    }

    #[test]
    fn write_behind_spill_commits_off_thread_and_runs_hook() {
        use std::sync::atomic::AtomicU32;
        let ex = Arc::new(Executor::new(2));
        let c = stack("wb", 100, 10_000).with_spill_executor(Arc::clone(&ex));
        let committed_tier: Arc<AtomicU32> = Arc::new(AtomicU32::new(99));
        assert_eq!(c.insert_with(sample(1, 100), None), Admit::Mem);
        let tier_probe = Arc::clone(&committed_tier);
        let admit = c.insert_with(
            sample(2, 100),
            Some(Box::new(move |tier| {
                tier_probe.store(
                    match tier {
                        Tier::Mem => 0,
                        Tier::Disk => 1,
                    },
                    Ordering::SeqCst,
                );
            })),
        );
        assert_eq!(admit, Admit::SpillQueued);
        c.drain_spills();
        assert_eq!(
            committed_tier.load(Ordering::SeqCst),
            1,
            "commit hook must run with Tier::Disk after the write"
        );
        let ts = c.tier_snapshot();
        assert_eq!(ts.spilled_offpath, 1);
        assert_eq!(ts.spilled_inline, 0);
        assert_eq!(ts.spill_bytes, 100);
        assert_eq!(ts.spill_queue_depth, 0);
        assert!(ts.spill_queue_peak >= 1);
        assert_eq!(c.get(2).unwrap().bytes, vec![2u8; 100]);
    }

    #[test]
    fn lookup_accounting_is_exact() {
        let c = stack("acct", 100, 10_000);
        assert!(c.insert(sample(1, 100))); // mem
        assert!(c.insert(sample(2, 100))); // disk
        let lookups = 30u64;
        for k in 0..lookups {
            let _ = c.get((k % 3) as u32); // 0 misses, 1 mem, 2 disk
        }
        let ts = c.tier_snapshot();
        assert_eq!(ts.mem_hits + ts.disk_hits + ts.misses, lookups);
        assert_eq!(ts.mem_hits, 10);
        assert_eq!(ts.disk_hits, 10);
        assert_eq!(ts.misses, 10);
    }

    #[test]
    fn mem_only_stack_matches_sample_cache_semantics() {
        let c = CacheStack::mem_only(250, Policy::InsertOnly);
        assert!(!c.has_disk_tier());
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(!c.insert(sample(3, 100)), "mem-only must reject when full");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_none());
        let ts = c.tier_snapshot();
        assert_eq!(ts.mem_hits, 1);
        assert_eq!(ts.disk_hits, 0);
        assert_eq!(ts.misses, 1);
        assert_eq!(ts.rejected, 1);
        assert_eq!(ts.disk_capacity, 0);
    }

    #[test]
    fn sweep_removes_only_dead_process_segments() {
        let dir = std::env::temp_dir().join(format!(
            "dlio-sweep-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Orphan: pid 4000000000 is above PID_MAX_LIMIT, so it cannot
        // be a live process on any Linux system.
        let orphan = dir.join("dlio-spill-4000000000-7-l2.seg");
        let orphan_stack = dir.join("dlio-stack-tag-with-dash-4000000000-ThreadId(9).spill");
        // Live: our own pid.
        let mine = dir.join(format!("dlio-spill-{}-1-l0.seg", std::process::id()));
        // Not ours to touch: unrelated names and wrong extensions.
        let unrelated = dir.join("checkpoint.bin");
        let wrong_ext = dir.join("dlio-spill-4000000000-7-l2.tmp");
        for f in [&orphan, &orphan_stack, &mine, &unrelated, &wrong_ext] {
            std::fs::write(f, b"x").unwrap();
        }
        let removed = sweep_orphaned_spills(&dir);
        assert_eq!(removed, 2, "exactly the two dead-owner segments");
        assert!(!orphan.exists());
        assert!(!orphan_stack.exists());
        assert!(mine.exists(), "live-process segments must survive");
        assert!(unrelated.exists());
        assert!(wrong_ext.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_of_missing_dir_is_a_noop() {
        let ghost = std::env::temp_dir().join("dlio-sweep-no-such-dir");
        assert_eq!(sweep_orphaned_spills(&ghost), 0);
    }
}
