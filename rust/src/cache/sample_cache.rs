//! Per-learner software sample cache (paper §III-C).
//!
//! Byte-capacity-bounded, insert-only ("no cache replacement after
//! populating caches in the first epoch"). Thread-safe: loader workers
//! populate it concurrently while the training loop reads. Samples are
//! shared via `Arc` so a cache hit never copies payload bytes.
//!
//! An optional LRU eviction mode exists for the *partial-cache* experiments
//! (paper §III-C discusses caching "a partial subset locally"), but the
//! locality-aware pipeline always runs insert-only, as the paper assumes.

use crate::storage::Sample;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Eviction policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Insert until full, then reject (the paper's model).
    InsertOnly,
    /// Evict least-recently-inserted when full (partial-cache studies).
    Fifo,
}

struct Inner {
    map: HashMap<u32, Arc<Sample>>,
    fifo: VecDeque<u32>,
    bytes: u64,
}

/// A learner's local sample cache.
pub struct SampleCache {
    inner: Mutex<Inner>,
    capacity_bytes: u64,
    policy: Policy,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SampleCache {
    pub fn new(capacity_bytes: u64, policy: Policy) -> Self {
        SampleCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                fifo: VecDeque::new(),
                bytes: 0,
            }),
            capacity_bytes,
            policy,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Insert a sample. Returns `false` if rejected (InsertOnly + full, or
    /// the sample alone exceeds the cache capacity).
    pub fn insert(&self, sample: Arc<Sample>) -> bool {
        let sz = sample.size() as u64;
        if sz > self.capacity_bytes {
            // An oversized sample can never fit: reject up front. (A Fifo
            // cache used to drain its *entire* contents before discovering
            // this — evicting everything and still returning `false`.)
            return false;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&sample.id) {
            return true; // already cached; idempotent
        }
        if inner.bytes + sz > self.capacity_bytes {
            match self.policy {
                Policy::InsertOnly => return false,
                Policy::Fifo => {
                    while inner.bytes + sz > self.capacity_bytes {
                        match inner.fifo.pop_front() {
                            Some(old) => {
                                if let Some(s) = inner.map.remove(&old) {
                                    inner.bytes -= s.size() as u64;
                                }
                            }
                            None => return false, // unreachable: sz <= cap
                        }
                    }
                }
            }
        }
        inner.bytes += sz;
        inner.fifo.push_back(sample.id);
        inner.map.insert(sample.id, sample);
        true
    }

    /// Look up a sample; counts hit/miss metrics.
    pub fn get(&self, id: u32) -> Option<Arc<Sample>> {
        let inner = self.inner.lock().unwrap();
        match inner.map.get(&id) {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(s))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching hit/miss counters.
    pub fn contains(&self, id: u32) -> bool {
        self.inner.lock().unwrap().map.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m == 0.0 { 0.0 } else { h / (h + m) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(id: u32, size: usize) -> Arc<Sample> {
        Arc::new(Sample { id, bytes: vec![id as u8; size].into(), label: 0 })
    }

    #[test]
    fn insert_get_roundtrip() {
        let c = SampleCache::new(1024, Policy::InsertOnly);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert_eq!(c.get(1).unwrap().bytes, vec![1u8; 100]);
        assert!(c.get(3).is_none());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.bytes(), 200);
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn insert_only_rejects_when_full() {
        let c = SampleCache::new(250, Policy::InsertOnly);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(!c.insert(sample(3, 100)), "must reject past capacity");
        assert_eq!(c.len(), 2);
        // The earlier entries survive.
        assert!(c.contains(1));
        assert!(c.contains(2));
    }

    #[test]
    fn duplicate_insert_is_idempotent() {
        let c = SampleCache::new(1000, Policy::InsertOnly);
        assert!(c.insert(sample(7, 100)));
        assert!(c.insert(sample(7, 100)));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn fifo_evicts_oldest() {
        let c = SampleCache::new(300, Policy::Fifo);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(c.insert(sample(3, 100)));
        assert!(c.insert(sample(4, 100))); // evicts 1
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3) && c.contains(4));
        assert_eq!(c.bytes(), 300);
    }

    #[test]
    fn oversized_sample_rejected_even_with_fifo() {
        let c = SampleCache::new(100, Policy::Fifo);
        assert!(!c.insert(sample(1, 200)));
        assert!(c.is_empty());
    }

    #[test]
    fn fifo_oversized_insert_does_not_evict_existing_entries() {
        // Regression: an oversized sample used to drain the whole Fifo
        // cache before being rejected. It must be rejected up front with
        // the resident set untouched.
        let c = SampleCache::new(300, Policy::Fifo);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(c.insert(sample(3, 100)));
        assert!(!c.insert(sample(4, 400)), "oversized must be rejected");
        assert!(
            c.contains(1) && c.contains(2) && c.contains(3),
            "rejection must not evict resident samples"
        );
        assert_eq!(c.bytes(), 300);
        // A fitting insert afterwards still evicts normally (oldest out).
        assert!(c.insert(sample(5, 100)));
        assert!(!c.contains(1));
        assert!(c.contains(5));
    }

    #[test]
    fn concurrent_population() {
        let c = Arc::new(SampleCache::new(u64::MAX, Policy::InsertOnly));
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u32 {
                    c.insert(sample(t * 500 + i, 16));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.len(), 4000);
        assert_eq!(c.bytes(), 4000 * 16);
        for id in 0..4000u32 {
            assert!(c.contains(id), "missing {id}");
        }
    }
}
