//! Per-learner software sample cache (paper §III-C).
//!
//! Byte-capacity-bounded, insert-only ("no cache replacement after
//! populating caches in the first epoch"). Thread-safe: loader workers
//! populate it concurrently while the training loop reads and remote
//! peers serve their hits from it. Samples are shared via `Arc` so a
//! cache hit never copies payload bytes.
//!
//! **Sharding.** The map is split into N independently locked shards
//! (id-hashed), so concurrent readers and writers only serialize when
//! they collide on the same shard — one global `Mutex` used to put every
//! loader worker, remote peer, and the training loop in one convoy.
//! Byte/entry/hit accounting lives in shard-independent atomics, so
//! `bytes()`/`len()` stay exact without locking anything: InsertOnly
//! capacity admission is a single atomic reservation
//! (`fetch_update`) performed under the owning shard's lock, which makes
//! over-admission impossible and keeps `bytes()` equal to the resident
//! payload at every instant.
//!
//! An optional LRU eviction mode exists for the *partial-cache*
//! experiments (paper §III-C discusses caching "a partial subset
//! locally"); Fifo runs **single-shard** so its global eviction order is
//! preserved — the locality-aware pipeline always runs insert-only (and
//! sharded), as the paper assumes.
//!
//! Lock acquisitions are counted via `try_lock`-then-block, so
//! `contention_rate()` exposes how often the sharded locks actually
//! collide (the `BENCH_hotpath.json` cache-shard-contention counter).

use crate::storage::Sample;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};

/// Eviction policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Insert until full, then reject (the paper's model).
    InsertOnly,
    /// Evict least-recently-inserted when full (partial-cache studies).
    Fifo,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u32, Arc<Sample>>,
    fifo: VecDeque<u32>,
}

/// A learner's local sample cache.
pub struct SampleCache {
    shards: Box<[Mutex<Shard>]>,
    capacity_bytes: u64,
    policy: Policy,
    bytes: AtomicU64,
    entries: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    lock_ops: AtomicU64,
    lock_contended: AtomicU64,
}

/// Shard count when the caller doesn't pick one: enough to spread the
/// loader workers, their decode-executor threads, remote peers and the
/// training loop, without making `len()`-style sweeps expensive. Fifo is
/// pinned to one shard so eviction order stays globally FIFO.
fn default_shards(policy: Policy) -> usize {
    match policy {
        Policy::Fifo => 1,
        Policy::InsertOnly => {
            let par = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(8);
            (par * 2).next_power_of_two().clamp(8, 64)
        }
    }
}

impl SampleCache {
    pub fn new(capacity_bytes: u64, policy: Policy) -> Self {
        Self::with_shards(capacity_bytes, policy, default_shards(policy))
    }

    /// As [`new`], with an explicit shard count (rounded up to a power of
    /// two; Fifo is always single-shard to keep global eviction order).
    ///
    /// [`new`]: SampleCache::new
    pub fn with_shards(
        capacity_bytes: u64,
        policy: Policy,
        shards: usize,
    ) -> Self {
        let n = match policy {
            Policy::Fifo => 1,
            Policy::InsertOnly => shards.max(1).next_power_of_two(),
        };
        SampleCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            capacity_bytes,
            policy,
            bytes: AtomicU64::new(0),
            entries: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            lock_ops: AtomicU64::new(0),
            lock_contended: AtomicU64::new(0),
        }
    }

    /// Fibonacci-hash the id so contiguous ids spread across shards.
    fn shard_index(&self, id: u32) -> usize {
        let h = (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        (h as usize) & (self.shards.len() - 1)
    }

    /// Lock a shard, counting how often the lock was actually contended.
    fn lock_shard(&self, id: u32) -> MutexGuard<'_, Shard> {
        self.lock_ops.fetch_add(1, Ordering::Relaxed);
        let m = &self.shards[self.shard_index(id)];
        match m.try_lock() {
            Ok(guard) => guard,
            Err(TryLockError::WouldBlock) => {
                self.lock_contended.fetch_add(1, Ordering::Relaxed);
                m.lock().unwrap()
            }
            Err(TryLockError::Poisoned(e)) => {
                panic!("poisoned cache shard: {e}")
            }
        }
    }

    /// Insert a sample. Returns `false` if rejected (InsertOnly + full, or
    /// the sample alone exceeds the cache capacity).
    pub fn insert(&self, sample: Arc<Sample>) -> bool {
        let sz = sample.size() as u64;
        if sz > self.capacity_bytes {
            // An oversized sample can never fit: reject up front. (A Fifo
            // cache used to drain its *entire* contents before discovering
            // this — evicting everything and still returning `false`.)
            return false;
        }
        let mut shard = self.lock_shard(sample.id);
        if shard.map.contains_key(&sample.id) {
            return true; // already cached; idempotent
        }
        match self.policy {
            Policy::InsertOnly => {
                // Atomic reservation: succeeds iff the bytes fit. Done
                // under the shard lock so a duplicate can't double-book,
                // while other shards admit concurrently.
                let cap = self.capacity_bytes;
                let reserved = self.bytes.fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |b| match b.checked_add(sz) {
                        Some(nb) if nb <= cap => Some(nb),
                        _ => None,
                    },
                );
                if reserved.is_err() {
                    return false;
                }
            }
            Policy::Fifo => {
                // Single shard: we hold the only lock, so the atomics
                // can't race with other mutators.
                while self.bytes.load(Ordering::Relaxed) + sz
                    > self.capacity_bytes
                {
                    match shard.fifo.pop_front() {
                        Some(old) => {
                            if let Some(s) = shard.map.remove(&old) {
                                self.bytes
                                    .fetch_sub(s.size() as u64, Ordering::Relaxed);
                                self.entries.fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                        None => return false, // unreachable: sz <= cap
                    }
                }
                self.bytes.fetch_add(sz, Ordering::Relaxed);
            }
        }
        shard.fifo.push_back(sample.id);
        shard.map.insert(sample.id, sample);
        self.entries.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Look up a sample; counts hit/miss metrics.
    pub fn get(&self, id: u32) -> Option<Arc<Sample>> {
        let shard = self.lock_shard(id);
        match shard.map.get(&id) {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(s))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching hit/miss counters.
    pub fn contains(&self, id: u32) -> bool {
        self.lock_shard(id).map.contains_key(&id)
    }

    /// Drop every resident sample (the cold-cache rejoin path). Hit/miss
    /// and lock counters are lifetime accounting and are kept.
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            let mut shard = shard.lock().unwrap();
            shard.map.clear();
            shard.fifo.clear();
        }
        self.bytes.store(0, Ordering::Relaxed);
        self.entries.store(0, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.entries.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m == 0.0 { 0.0 } else { h / (h + m) }
    }

    /// Total shard-lock acquisitions (every insert/get/contains is one).
    pub fn lock_ops(&self) -> u64 {
        self.lock_ops.load(Ordering::Relaxed)
    }

    /// How many of those acquisitions found the shard lock held.
    pub fn lock_contended(&self) -> u64 {
        self.lock_contended.load(Ordering::Relaxed)
    }

    /// Fraction of lock acquisitions that actually contended — the
    /// cache-shard-contention number in `BENCH_hotpath.json`.
    pub fn contention_rate(&self) -> f64 {
        let ops = self.lock_ops() as f64;
        if ops == 0.0 { 0.0 } else { self.lock_contended() as f64 / ops }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(id: u32, size: usize) -> Arc<Sample> {
        Arc::new(Sample { id, bytes: vec![id as u8; size].into(), label: 0 })
    }

    #[test]
    fn insert_get_roundtrip() {
        let c = SampleCache::new(1024, Policy::InsertOnly);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert_eq!(c.get(1).unwrap().bytes, vec![1u8; 100]);
        assert!(c.get(3).is_none());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.bytes(), 200);
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn insert_only_rejects_when_full() {
        let c = SampleCache::new(250, Policy::InsertOnly);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(!c.insert(sample(3, 100)), "must reject past capacity");
        assert_eq!(c.len(), 2);
        // The earlier entries survive.
        assert!(c.contains(1));
        assert!(c.contains(2));
        // Rejection must not leak reserved bytes.
        assert_eq!(c.bytes(), 200);
    }

    #[test]
    fn duplicate_insert_is_idempotent() {
        let c = SampleCache::new(1000, Policy::InsertOnly);
        assert!(c.insert(sample(7, 100)));
        assert!(c.insert(sample(7, 100)));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn fifo_evicts_oldest() {
        let c = SampleCache::new(300, Policy::Fifo);
        assert_eq!(c.shard_count(), 1, "Fifo must stay single-shard");
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(c.insert(sample(3, 100)));
        assert!(c.insert(sample(4, 100))); // evicts 1
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3) && c.contains(4));
        assert_eq!(c.bytes(), 300);
    }

    #[test]
    fn oversized_sample_rejected_even_with_fifo() {
        let c = SampleCache::new(100, Policy::Fifo);
        assert!(!c.insert(sample(1, 200)));
        assert!(c.is_empty());
    }

    #[test]
    fn fifo_oversized_insert_does_not_evict_existing_entries() {
        // Regression: an oversized sample used to drain the whole Fifo
        // cache before being rejected. It must be rejected up front with
        // the resident set untouched.
        let c = SampleCache::new(300, Policy::Fifo);
        assert!(c.insert(sample(1, 100)));
        assert!(c.insert(sample(2, 100)));
        assert!(c.insert(sample(3, 100)));
        assert!(!c.insert(sample(4, 400)), "oversized must be rejected");
        assert!(
            c.contains(1) && c.contains(2) && c.contains(3),
            "rejection must not evict resident samples"
        );
        assert_eq!(c.bytes(), 300);
        // A fitting insert afterwards still evicts normally (oldest out).
        assert!(c.insert(sample(5, 100)));
        assert!(!c.contains(1));
        assert!(c.contains(5));
    }

    #[test]
    fn concurrent_population() {
        let c = Arc::new(SampleCache::new(u64::MAX, Policy::InsertOnly));
        assert!(c.shard_count() >= 8);
        assert!(c.shard_count().is_power_of_two());
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u32 {
                    c.insert(sample(t * 500 + i, 16));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.len(), 4000);
        assert_eq!(c.bytes(), 4000 * 16);
        for id in 0..4000u32 {
            assert!(c.contains(id), "missing {id}");
        }
    }

    #[test]
    fn capacity_is_never_over_admitted_across_shards() {
        // 64 threads race to insert 100-byte samples into a 32-sample
        // budget; the atomic reservation must admit exactly 32 no matter
        // how the shard locks interleave.
        let c = Arc::new(SampleCache::with_shards(
            3200,
            Policy::InsertOnly,
            16,
        ));
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut admitted = 0u32;
                for i in 0..100u32 {
                    if c.insert(sample(t * 100 + i, 100)) {
                        admitted += 1;
                    }
                }
                admitted
            }));
        }
        let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 32);
        assert_eq!(c.len(), 32);
        assert_eq!(c.bytes(), 3200);
    }

    #[test]
    fn shard_sum_accounting_exact_under_reader_writer_peer_contention() {
        // The sharded-rewrite acceptance test: hammer one cache from
        // writer threads (loader population), reader threads (training
        // loop lookups) and "remote peer" threads (get + re-insert of
        // other ids) simultaneously, then check every aggregate —
        // bytes(), len(), hits()+misses() — against exact expectations.
        let c = Arc::new(SampleCache::with_shards(
            u64::MAX,
            Policy::InsertOnly,
            16,
        ));
        let n: u32 = 2000;
        let sz: usize = 32;
        let mut handles = Vec::new();
        // 4 writers insert disjoint id ranges (duplicates via overlap
        // rounds must stay idempotent).
        for w in 0..4u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _round in 0..2 {
                    for i in 0..(n / 4) {
                        let id = w * (n / 4) + i;
                        assert!(c.insert(sample(id, sz)));
                    }
                }
                (0u64, 0u64)
            }));
        }
        // 3 readers + 2 peers issue gets and count their own hit/miss
        // tallies so the cache counters can be cross-checked exactly.
        for r in 0..5u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut hits = 0u64;
                let mut misses = 0u64;
                for i in 0..3000u32 {
                    let id = (i * 7 + r * 13) % (n + 500); // some misses
                    match c.get(id) {
                        Some(s) => {
                            assert_eq!(s.id, id);
                            assert_eq!(s.bytes.len(), sz);
                            hits += 1;
                        }
                        None => misses += 1,
                    }
                }
                (hits, misses)
            }));
        }
        let mut expect_hits = 0u64;
        let mut expect_misses = 0u64;
        for h in handles {
            let (hits, misses) = h.join().unwrap();
            expect_hits += hits;
            expect_misses += misses;
        }
        assert_eq!(c.len(), n as usize);
        assert_eq!(c.bytes(), n as u64 * sz as u64);
        assert_eq!(c.hits(), expect_hits);
        assert_eq!(c.misses(), expect_misses);
        assert_eq!(c.hits() + c.misses(), 5 * 3000);
        for id in 0..n {
            assert!(c.contains(id), "missing {id}");
        }
        // Every operation took exactly one shard lock.
        assert_eq!(
            c.lock_ops(),
            // inserts (2 rounds × n) + gets (5 × 3000) + the `contains`
            // sweep (n) just above.
            2 * n as u64 + 5 * 3000 + n as u64
        );
        assert!(c.contention_rate() <= 1.0);
    }
}
