//! Software caching (paper §III-C): per-learner sample caches, the
//! replicated cache directory, and the aggregated-cache view used by the
//! locality-aware sampler.

pub mod directory;
pub mod sample_cache;
pub mod tiered;

pub use directory::CacheDirectory;
pub use sample_cache::{Policy, SampleCache};
pub use tiered::TieredCache;

use crate::storage::Sample;
use std::sync::Arc;

/// The aggregated (distributed) cache: every learner's local cache plus the
/// shared directory. In-process stand-in for the paper's node-spanning
/// cache — learner `j`'s cache is reachable from any learner, with the
/// interconnect cost accounted by [`crate::net::Fabric`].
pub struct AggregatedCache {
    caches: Vec<Arc<SampleCache>>,
    directory: CacheDirectory,
}

impl AggregatedCache {
    pub fn new(caches: Vec<Arc<SampleCache>>, n_samples: u64) -> Self {
        let directory = CacheDirectory::new(n_samples);
        AggregatedCache { caches, directory }
    }

    pub fn p(&self) -> usize {
        self.caches.len()
    }

    pub fn directory(&self) -> &CacheDirectory {
        &self.directory
    }

    pub fn cache(&self, learner: usize) -> &Arc<SampleCache> {
        &self.caches[learner]
    }

    /// Insert into `learner`'s cache and update the directory. Returns
    /// whether the cache accepted the sample. Takes `&self`: the caches
    /// synchronize internally and the directory is lock-free.
    pub fn insert(&self, learner: usize, sample: Arc<Sample>) -> bool {
        let id = sample.id;
        if self.caches[learner].insert(sample) {
            self.directory.set_owner(id, learner);
            true
        } else {
            false
        }
    }

    /// Fetch a sample from whichever cache owns it.
    pub fn fetch(&self, id: u32) -> Option<(usize, Arc<Sample>)> {
        let owner = self.directory.owner(id)?;
        self.caches[owner].get(id).map(|s| (owner, s))
    }

    /// The paper's α.
    pub fn alpha(&self) -> f64 {
        self.directory.alpha()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(id: u32) -> Arc<Sample> {
        Arc::new(Sample { id, bytes: vec![id as u8; 8].into(), label: 0 })
    }

    fn agg(p: usize, cap: u64, n: u64) -> AggregatedCache {
        let caches = (0..p)
            .map(|_| Arc::new(SampleCache::new(cap, Policy::InsertOnly)))
            .collect();
        AggregatedCache::new(caches, n)
    }

    #[test]
    fn insert_updates_directory_and_fetch_routes() {
        let a = agg(3, 1024, 100);
        assert!(a.insert(1, sample(42)));
        assert_eq!(a.directory().owner(42), Some(1));
        let (owner, s) = a.fetch(42).unwrap();
        assert_eq!(owner, 1);
        assert_eq!(s.id, 42);
        assert!(a.fetch(43).is_none());
    }

    #[test]
    fn rejected_insert_leaves_directory_clean() {
        let a = agg(2, 8, 10); // capacity: exactly one 8-byte sample
        assert!(a.insert(0, sample(1)));
        assert!(!a.insert(0, sample(2)));
        assert_eq!(a.directory().owner(2), None);
        assert!((a.alpha() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn disjoint_population_alpha_reaches_one() {
        let a = agg(4, u64::MAX, 40);
        for id in 0..40u32 {
            assert!(a.insert(id as usize % 4, sample(id)));
        }
        assert_eq!(a.alpha(), 1.0);
        for id in 0..40u32 {
            let (owner, _) = a.fetch(id).unwrap();
            assert_eq!(owner, id as usize % 4);
        }
    }
}
