//! Software caching (paper §III-C): per-learner hierarchical cache stacks
//! (DRAM + SSD spill tier), the replicated cache directory, and the
//! aggregated-cache view used by the locality-aware sampler.

pub mod directory;
pub mod sample_cache;
pub mod stack;

pub use directory::CacheDirectory;
pub use sample_cache::{Policy, SampleCache};
pub use stack::{
    sweep_orphaned_spills, Admit, CacheStack, CommitHook, DiskTier, Lookup,
    SpillConfig,
};

use crate::storage::Sample;
use std::sync::Arc;

/// Which tier of a learner's [`CacheStack`] holds a sample. Distinct
/// tiers cost differently to hit (DRAM vs SSD) — the directory records
/// the tier alongside the owner so the whole pipeline (fetch routing,
/// sim/analytic Eq. 7) can model the hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// DRAM tier (the sharded [`SampleCache`]).
    Mem,
    /// SSD spill tier (mmap-backed reads).
    Disk,
}

/// The aggregated (distributed) cache: every learner's local cache stack
/// plus the shared directory. In-process stand-in for the paper's
/// node-spanning cache — learner `j`'s stack is reachable from any
/// learner, with the interconnect cost accounted by [`crate::net::Fabric`].
pub struct AggregatedCache {
    caches: Vec<Arc<CacheStack>>,
    directory: Arc<CacheDirectory>,
}

impl AggregatedCache {
    pub fn new(caches: Vec<Arc<CacheStack>>, n_samples: u64) -> Self {
        let directory = Arc::new(CacheDirectory::new(n_samples));
        AggregatedCache { caches, directory }
    }

    pub fn p(&self) -> usize {
        self.caches.len()
    }

    pub fn directory(&self) -> &CacheDirectory {
        &self.directory
    }

    pub fn cache(&self, learner: usize) -> &Arc<CacheStack> {
        &self.caches[learner]
    }

    /// Insert into `learner`'s stack and update the directory (for a
    /// write-behind spill the claim is published by the commit hook once
    /// the bytes are servable). Returns whether the stack accepted the
    /// sample. Takes `&self`: the stacks synchronize internally and the
    /// directory is lock-free.
    pub fn insert(&self, learner: usize, sample: Arc<Sample>) -> bool {
        let id = sample.id;
        let directory = Arc::clone(&self.directory);
        let admit = self.caches[learner].insert_with(
            sample,
            Some(Box::new(move |tier| {
                directory.set_owner_tier(id, learner, tier);
            })),
        );
        !matches!(admit, Admit::Rejected)
    }

    /// Fetch a sample from whichever stack owns it.
    pub fn fetch(&self, id: u32) -> Option<(usize, Arc<Sample>)> {
        let owner = self.directory.owner(id)?;
        self.caches[owner].get(id).map(|s| (owner, s))
    }

    /// The paper's α.
    pub fn alpha(&self) -> f64 {
        self.directory.alpha()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(id: u32) -> Arc<Sample> {
        Arc::new(Sample { id, bytes: vec![id as u8; 8].into(), label: 0 })
    }

    fn agg(p: usize, cap: u64, n: u64) -> AggregatedCache {
        let caches = (0..p)
            .map(|_| Arc::new(CacheStack::mem_only(cap, Policy::InsertOnly)))
            .collect();
        AggregatedCache::new(caches, n)
    }

    #[test]
    fn insert_updates_directory_and_fetch_routes() {
        let a = agg(3, 1024, 100);
        assert!(a.insert(1, sample(42)));
        assert_eq!(a.directory().owner(42), Some(1));
        assert_eq!(a.directory().owner_tier(42), Some((1, Tier::Mem)));
        let (owner, s) = a.fetch(42).unwrap();
        assert_eq!(owner, 1);
        assert_eq!(s.id, 42);
        assert!(a.fetch(43).is_none());
    }

    #[test]
    fn rejected_insert_leaves_directory_clean() {
        let a = agg(2, 8, 10); // capacity: exactly one 8-byte sample
        assert!(a.insert(0, sample(1)));
        assert!(!a.insert(0, sample(2)));
        assert_eq!(a.directory().owner(2), None);
        assert!((a.alpha() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn disjoint_population_alpha_reaches_one() {
        let a = agg(4, u64::MAX, 40);
        for id in 0..40u32 {
            assert!(a.insert(id as usize % 4, sample(id)));
        }
        assert_eq!(a.alpha(), 1.0);
        for id in 0..40u32 {
            let (owner, _) = a.fetch(id).unwrap();
            assert_eq!(owner, id as usize % 4);
        }
    }

    #[test]
    fn tiered_member_publishes_disk_claims() {
        // One learner's stack overflows its DRAM tier; spilled members are
        // claimed in the directory with Tier::Disk and stay fetchable.
        let spill = SpillConfig {
            path: std::env::temp_dir().join(format!(
                "dlio-agg-{}.spill",
                std::process::id()
            )),
            capacity_bytes: 4096,
            read_latency: std::time::Duration::ZERO,
        };
        let caches = vec![Arc::new(
            CacheStack::tiered(16, Policy::InsertOnly, &spill).unwrap(),
        )];
        let a = AggregatedCache::new(caches, 10);
        assert!(a.insert(0, sample(1))); // 8B: mem
        assert!(a.insert(0, sample(2))); // 8B: mem full
        assert!(a.insert(0, sample(3))); // spills (inline)
        assert_eq!(a.directory().owner_tier(2), Some((0, Tier::Mem)));
        assert_eq!(a.directory().owner_tier(3), Some((0, Tier::Disk)));
        assert_eq!(a.directory().tier_counts(), (2, 1));
        let (owner, s) = a.fetch(3).unwrap();
        assert_eq!(owner, 0);
        assert!(s.bytes.is_zero_copy(), "disk hit must be an mmap view");
    }
}
