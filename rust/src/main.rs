//! `dlio` — launcher for the locality-aware data-loading stack.
//!
//! Subcommands:
//!   gen-data   materialize a synthetic shard dataset
//!   loadtest   run the live loader (Fig. 7-style sweep or single config)
//!   train      distributed training on a materialized dataset (Reg/Loc);
//!              with --procs N, supervised multi-process scale-out
//!   worker     (internal) one multi-process rank, spawned by the supervisor
//!   figures    regenerate a paper figure/table (sim- or live-backed)
//!   analytic   print the §IV model curves
//!   balance    demo Algorithm 1 on a load vector
//!
//! Run `dlio <cmd> --help` semantics: every option has a default; see the
//! match arms below for the accepted keys.
//!
//! Exit codes map the terminal error class (DESIGN.md §13): 0 clean,
//! 1 crash, 40-43 the four deadline-stall kinds, 44 injected kill.

use anyhow::{bail, Context, Result};
use dlio::config::Args;
use dlio::coordinator::{SamplerKind, Trainer, TrainerConfig};
use dlio::fault::netchaos::NetChaosSpec;
use dlio::fault::{exitcode, Deadlines, ProcKill};
use dlio::loader::LoaderConfig;
use dlio::net::transport::{NetTuning, TransportKind};
use dlio::net::{Fabric, FabricConfig};
use dlio::runtime::{default_artifacts_dir, Engine};
use dlio::storage::{
    generate, Catalog, StorageEngine, StorageSystem, SyntheticSpec, TokenBucket,
};
use dlio::{analytic, figures};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    match run() {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(exitcode::classify(&e));
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("gen-data") => gen_data(&args),
        Some("loadtest") => loadtest(&args),
        Some("train") => train(&args),
        Some("worker") => dlio::coordinator::worker_main(&args),
        Some("figures") => run_figures(&args),
        Some("analytic") => run_analytic(&args),
        Some("balance") => balance_demo(&args),
        Some(other) => bail!("unknown subcommand {other:?}; see src/main.rs"),
        None => {
            eprintln!(
                "usage: dlio <gen-data|loadtest|train|figures|analytic|balance> [--key value]..."
            );
            Ok(())
        }
    }
}

fn data_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("dir", "/tmp/dlio-data"))
}

fn gen_data(args: &Args) -> Result<()> {
    let dir = data_dir(args);
    let spec = SyntheticSpec {
        n_samples: args.u64_or("samples", 4096)?,
        n_classes: args.usize_or("classes", 16)? as u16,
        samples_per_shard: args.u64_or("shard", 1024)?,
        noise: args.usize_or("noise", 24)? as u8,
        ambiguity: args.f64_or("ambiguity", 0.0)?,
        seed: args.u64_or("seed", 1234)?,
        ..Default::default()
    };
    let meta = generate(&dir, &spec)?;
    println!(
        "generated {} samples ({} shards, {} classes) under {}",
        meta.n_samples,
        meta.shards.len(),
        meta.n_classes,
        dir.display()
    );
    Ok(())
}

fn loadtest(args: &Args) -> Result<()> {
    let dir = data_dir(args);
    if !dir.join("dataset.json").exists() {
        bail!("no dataset at {} — run `dlio gen-data --dir ...`", dir.display());
    }
    let cfg = figures::Fig7Config {
        data_dir: dir,
        batches: args.usize_or("batches", 16)?,
        batch_size: args.usize_or("batch", 64)?,
        ..Default::default()
    };
    let workers = args.usize_list_or("workers", &[1, 2, 4, 8, 10])?;
    let threads = args.usize_list_or("threads", &[0, 2, 4])?;
    let rows = figures::fig7(&cfg, &workers, &threads)?;
    figures::print_fig7(&rows);
    Ok(())
}

/// Network tuning from CLI flags (DESIGN.md §14). Returns `None` when no
/// tuning flag is present, so the zero-flag path keeps the legacy
/// defaults exactly; any flag pulls in `NetTuning::default()` for the
/// rest. Validation happens at the consumer (`Trainer::new` /
/// `run_multiproc`).
fn net_tuning(args: &Args) -> Result<Option<NetTuning>> {
    const KEYS: [&str; 5] = [
        "hb-interval-ms",
        "hb-timeout-ms",
        "transfer-deadline-ms",
        "reconnect-base-ms",
        "reconnect-cap-ms",
    ];
    if KEYS.iter().all(|k| args.str_opt(k).is_none()) {
        return Ok(None);
    }
    let d = NetTuning::default();
    let ms = |key: &str, dflt: Duration| -> Result<Duration> {
        Ok(Duration::from_millis(
            args.u64_or(key, dflt.as_millis() as u64)?,
        ))
    };
    Ok(Some(NetTuning {
        hb_interval: ms("hb-interval-ms", d.hb_interval)?,
        hb_timeout: ms("hb-timeout-ms", d.hb_timeout)?,
        transfer_deadline: ms("transfer-deadline-ms", d.transfer_deadline)?,
        reconnect_base: ms("reconnect-base-ms", d.reconnect_base)?,
        reconnect_cap: ms("reconnect-cap-ms", d.reconnect_cap)?,
    }))
}

/// Wire-level chaos spec from `--chaos-*` flags (DESIGN.md §14).
/// Returns `None` when the resulting spec is inert — the common case —
/// so the supervisor's "chaos requires TCP" guard only fires when
/// injection could actually happen.
fn net_chaos(args: &Args) -> Result<Option<NetChaosSpec>> {
    let spec = NetChaosSpec {
        seed: args.u64_or("chaos-seed", 0xC4A05)?,
        tear_every: args.u64_or("chaos-tear-every", 0)?,
        flip_every: args.u64_or("chaos-flip-every", 0)?,
        connect_drop_every: args.u64_or("chaos-drop-connect-every", 0)?,
        accept_refuse_every: args.u64_or("chaos-refuse-accept-every", 0)?,
        delay_every: args.u64_or("chaos-delay-every", 0)?,
        delay_ms: args.u64_or("chaos-delay-ms", 0)?,
        partitions: match args.str_opt("chaos-partitions") {
            None => Vec::new(),
            Some(s) => s
                .split(',')
                .map(|t| {
                    NetChaosSpec::parse_partition(t.trim()).with_context(|| {
                        format!(
                            "bad --chaos-partitions entry {t:?} \
                             (want a:b:from:to)"
                        )
                    })
                })
                .collect::<Result<_>>()?,
        },
    };
    Ok((!spec.is_inert()).then_some(spec))
}

fn train(args: &Args) -> Result<()> {
    let dir = data_dir(args);
    let sampler = match args.str_or("sampler", "loc").as_str() {
        "reg" => SamplerKind::Reg,
        "distcache" | "dc" => SamplerKind::DistCache,
        "loc" => SamplerKind::Loc,
        other => bail!("--sampler must be reg|distcache|loc, got {other:?}"),
    };
    // --procs N routes to the supervised multi-process tier: one child
    // process per node over real transports (DESIGN.md §13).
    if args.usize_or("procs", 0)? > 0 {
        return train_multiproc(args, dir, sampler);
    }
    if !dir.join("dataset.json").exists() {
        println!("materializing default dataset under {}", dir.display());
        generate(&dir, &SyntheticSpec::default())?;
    }
    let throttle = match args.f64_or("storage-bps", 0.0)? {
        bps if bps > 0.0 => Some(Arc::new(TokenBucket::new(bps, 64.0 * 1024.0))),
        _ => None,
    };
    let engine = Arc::new(Engine::load(&default_artifacts_dir())?);
    // --storage-engine auto|pread|uring selects the batched submission
    // backend (DESIGN.md §15); `auto` uses io_uring only when the crate
    // was built with the `uring` feature AND the kernel admits it.
    let storage_engine =
        StorageEngine::parse(&args.str_or("storage-engine", "auto"))?;
    let storage =
        Arc::new(StorageSystem::open_engine(&dir, throttle, storage_engine)?);
    let fabric = Arc::new(Fabric::new(FabricConfig {
        real_time: args.flag("real-fabric"),
        ..Default::default()
    }));
    let cfg = TrainerConfig {
        p: args.usize_or("p", 2)?,
        epochs: args.u64_or("epochs", 2)?,
        local_batch: args.usize_or("batch", 16)?,
        lr: args.f64_or("lr", 0.05)? as f32,
        sampler,
        loader: LoaderConfig {
            workers: args.usize_or("workers", 2)?,
            threads_per_worker: args.usize_or("threads", 2)?,
            prefetch_batches: args.usize_or("prefetch", 2)?,
        },
        seed: args.u64_or("seed", 42)?,
        cache_capacity_bytes: args.bytes_or("cache-bytes", u64::MAX)?,
        disk_cache_capacity_bytes: args.bytes_or("disk-cache-bytes", 0)?,
        disk_latency_s: args.f64_or("disk-latency", 0.0)?,
        spill_dir: args.str_opt("spill-dir").map(PathBuf::from),
        flip_prob: args.f64_or("flip", 0.5)?,
        decode_s_per_kib: args.f64_or("decode", 0.0)?,
        eval_samples: args.usize_or("eval", 0)?,
        checkpoint_path: args.str_opt("checkpoint").map(PathBuf::from),
        // Fault injection & straggler mitigation (DESIGN.md §11):
        //   --fault-node 1 --fault-link-scale 0.5   degrade node 1's links
        //   --fault-disk-scale 0.5                  degrade its storage reads
        //   --fault-dead                            dead-owner mode
        //   --rebalance-interval 0.05               enable the monitor
        fault_node: args
            .str_opt("fault-node")
            .map(|s| s.parse().context("bad --fault-node"))
            .transpose()?,
        fault_link_scale: args.f64_or("fault-link-scale", 1.0)?,
        fault_disk_scale: args.f64_or("fault-disk-scale", 1.0)?,
        fault_dead: args.flag("fault-dead"),
        fault_seed: args.u64_or("fault-seed", 0x5EED)?,
        rebalance_interval_s: args.f64_or("rebalance-interval", 0.0)?,
        // Failure recovery (DESIGN.md §12): uniform stall deadline,
        // step-granular checkpoints, resume, and the halt fault.
        deadlines: match args.u64_or("deadline-ms", 0)? {
            0 => Deadlines::none(),
            ms => Deadlines::uniform(Duration::from_millis(ms)),
        },
        checkpoint_interval_steps: args.u64_or("checkpoint-interval", 0)?,
        resume_from: args.str_opt("resume").map(PathBuf::from),
        halt_after_gstep: match args.u64_or("halt-after", 0)? {
            0 => None,
            s => Some(s),
        },
        // Storage wave model + NUMA placement (DESIGN.md §15):
        //   --storage-latency 0.002   per-request device latency; blocking
        //                             reads pay it per coalesced run, waves
        //                             once per submission wave
        //   --numa-pin                probe sysfs topology and pin decode/
        //                             spill executor shards per learner
        storage_latency_s: args.f64_or("storage-latency", 0.0)?,
        numa_pin: args.flag("numa-pin"),
        // Network tuning (DESIGN.md §14): only installed when a flag is
        // present, so default runs stay bit-identical.
        net: net_tuning(args)?,
        ..TrainerConfig::default()
    };
    println!(
        "training: p={} epochs={} B_local={} sampler={:?} (engine: {})",
        cfg.p,
        cfg.epochs,
        cfg.local_batch,
        cfg.sampler,
        engine.platform()
    );
    let report = Trainer::new(engine, storage, fabric, cfg)?.run()?;
    println!("{}", dlio::metrics::EpochReport::markdown_header());
    for e in &report.epochs {
        println!("{}", e.markdown_row());
    }
    if let Some(acc) = report.final_accuracy {
        println!("final accuracy: {:.2}%", acc * 100.0);
    }
    println!(
        "learners in sync: {}; mean grad step: {:.1} ms",
        report.learners_in_sync(),
        report.mean_grad_exec_s * 1e3
    );
    let st = report.stall_total();
    println!(
        "stalls: fetch {:.2}s prep {:.2}s barrier {:.2}s \
         (barrier share {:.0}%)",
        st.fetch_s,
        st.prep_s,
        st.barrier_s,
        st.barrier_share() * 100.0
    );
    if report.tiers.disk_capacity > 0 {
        println!(
            "cache tiers: mem hits {:.1}% disk hits {:.1}% | spilled \
             {:.1} MiB ({:.0}% off-path) | disk-hit copied bytes {}",
            report.tiers.mem_hit_ratio() * 100.0,
            report.tiers.disk_hit_ratio() * 100.0,
            report.tiers.spill_bytes as f64 / (1024.0 * 1024.0),
            report.tiers.spill_offpath_ratio() * 100.0,
            report.tiers.disk_hit_copied_bytes,
        );
        if report.tiers.spill_failures > 0 {
            eprintln!(
                "WARNING: {} spill write(s) failed — those samples are \
                 uncached and re-read from storage every epoch",
                report.tiers.spill_failures
            );
        }
    }
    Ok(())
}

/// `dlio train --procs N [--transport uds] [--kill-rank R --kill-step S
/// [--restart]]` — supervised multi-process training over real
/// transports, with optional SIGKILL injection.
fn train_multiproc(
    args: &Args,
    dir: PathBuf,
    sampler: SamplerKind,
) -> Result<()> {
    let transport_str = args.str_or("transport", "uds");
    let transport = TransportKind::parse(&transport_str)
        .with_context(|| format!("unknown --transport {transport_str}"))?;
    let kill = match args.str_opt("kill-rank") {
        Some(r) => Some(ProcKill {
            rank: r.parse().context("bad --kill-rank")?,
            at_gstep: args.u64_or("kill-step", 1)?,
        }),
        None => None,
    };
    let cfg = dlio::coordinator::MultiProcConfig {
        procs: args.usize_or("procs", 2)?,
        learners_per_proc: args.usize_or("learners", 2)?,
        epochs: args.u64_or("epochs", 2)?,
        local_batch: args.usize_or("batch", 8)?,
        data_dir: dir,
        samples: args.u64_or("samples", 256)?,
        seed: args.u64_or("seed", 42)?,
        lr: args.f64_or("lr", 0.05)?,
        flip_prob: args.f64_or("flip", 0.5)?,
        sampler,
        transport,
        worker_bin: std::env::current_exe()?,
        kill,
        restart: args.flag("restart"),
        bench_out: args.str_opt("bench-out").map(PathBuf::from),
        // Multi-host TCP knobs (DESIGN.md §14): bind address, static
        // peer table, network tuning, and the wire-chaos spec. All
        // default to off; `run_multiproc` rejects chaos over UDS.
        net: net_tuning(args)?.unwrap_or_default(),
        listen: args.str_opt("listen"),
        peers: args.str_opt("peers").map(|s| {
            s.split(',').map(|t| t.trim().to_string()).collect()
        }),
        chaos: net_chaos(args)?,
        ..dlio::coordinator::MultiProcConfig::default()
    };
    println!(
        "multi-process training: {} procs x {} learners, transport {}",
        cfg.procs,
        cfg.learners_per_proc,
        cfg.transport.as_str()
    );
    let report = dlio::coordinator::run_multiproc(&cfg)?;
    println!(
        "digest {:#018x} | steps {} | wall {:.2}s | membership epoch {} \
         (deaths {}, revivals {})",
        report.coord.digest,
        report.coord.steps,
        report.coord.wall_s,
        report.coord.recovery.membership_epoch,
        report.coord.recovery.deaths,
        report.coord.recovery.revivals,
    );
    for (rank, code, signal) in &report.exits {
        println!(
            "  rank {rank}: {}",
            dlio::coordinator::SupervisorReport::describe_exit(*code, *signal)
        );
    }
    Ok(())
}

fn run_figures(args: &Args) -> Result<()> {
    let which = args.str_or("fig", "all");
    let quick = args.flag("quick");
    let scales: Vec<usize> = if quick {
        vec![2, 8, 16, 64, 256]
    } else {
        vec![2, 4, 8, 16, 32, 64, 128, 256]
    };
    let loading_scales: Vec<usize> = if quick {
        vec![8, 64, 256]
    } else {
        vec![8, 16, 32, 64, 128, 256]
    };
    let run = |f: &str| which == "all" || which == f;

    if run("1") {
        figures::print_fig1(&figures::fig1(&scales));
    }
    if run("6") {
        let rows = figures::fig6(
            if quick { &[16, 64] } else { &[4, 16, 64, 256] },
            &[32, 64, 128],
        );
        figures::print_fig6(&rows);
    }
    if run("7") {
        let dir = data_dir(args);
        let fig7dir = if dir.join("dataset.json").exists() {
            dir
        } else {
            let d = std::env::temp_dir().join("dlio-fig7-data");
            if !d.join("dataset.json").exists() {
                generate(
                    &d,
                    &SyntheticSpec { n_samples: 2048, ..Default::default() },
                )?;
            }
            d
        };
        let cfg = figures::Fig7Config {
            data_dir: fig7dir,
            batches: if quick { 4 } else { 12 },
            ..Default::default()
        };
        let rows = figures::fig7(
            &cfg,
            if quick { &[1, 4, 10] } else { &[1, 2, 4, 6, 8, 10] },
            if quick { &[0, 4] } else { &[0, 1, 2, 4, 8] },
        )?;
        figures::print_fig7(&rows);
    }
    for (fig, catalog) in [
        ("8", Catalog::imagenet_1k()),
        ("9", Catalog::ucf101_rgb()),
        ("10", Catalog::ucf101_flow()),
        ("11", Catalog::mummi()),
    ] {
        if run(fig) {
            let nodes: Vec<usize> = if fig == "11" {
                // The paper evaluates MuMMI at 16..128 nodes (512 learners).
                loading_scales.iter().copied().filter(|&n| n <= 128).collect()
            } else {
                loading_scales.clone()
            };
            let rows = figures::dataset_scaling(&catalog, &nodes);
            figures::print_dataset_scaling(
                &format!("Fig. {fig} — {}", catalog.name),
                &rows,
            );
        }
    }
    if run("12") {
        let v = args.f64_or("v-node", 0.0)?;
        let rows =
            figures::fig12(&[16, 32, 64], (v > 0.0).then_some(v));
        figures::print_fig12(&rows);
    }
    Ok(())
}

fn run_analytic(args: &Args) -> Result<()> {
    let m = analytic::lassen_imagenet();
    let nodes = args.usize_list_or("nodes", &[2, 4, 8, 16, 32, 64, 128, 256])?;
    println!("crossover p* = R/V = {:.1} nodes (Eq. 5)", m.crossover_p());
    println!("| p | train s | load s (Eq.4) | true cost (Eq.6) | distcache io (Eq.7) | loc io (Eq.8) |");
    println!("|---|---|---|---|---|---|");
    for p in nodes {
        println!(
            "| {p} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} |",
            m.training_time(p),
            m.loading_time_plain(p),
            m.true_cost_plain(p),
            m.io_time_distcache(p),
            m.io_time_loc(p),
        );
    }
    Ok(())
}

fn balance_demo(args: &Args) -> Result<()> {
    let loads: Vec<u64> = args
        .str_or("loads", "2,6,4")
        .split(',')
        .map(|t| t.trim().parse().context("bad load"))
        .collect::<Result<_>>()?;
    println!("loads:   {loads:?}");
    println!("targets: {:?}", dlio::balance::targets(&loads));
    let schedule = dlio::balance::balance(&loads);
    for t in &schedule {
        println!("  transfer {} samples: learner {} -> {}", t.amount, t.from, t.to);
    }
    println!(
        "{} transfers, {} samples moved ({:.1}% of batch)",
        schedule.len(),
        dlio::balance::moved(&schedule),
        100.0 * dlio::balance::moved(&schedule) as f64
            / loads.iter().sum::<u64>().max(1) as f64
    );
    Ok(())
}
