//! Shared epoch-partition planner: compute Loc/Reg assignments **once**
//! per process, off the training critical path.
//!
//! The paper's locality-aware scheme (§V-A, Algorithm 1) lets every
//! learner derive the *same* partition from the replicated directory with
//! no communication. Deriving it on every learner is what makes the
//! scheme coordination-free across *nodes* — but inside one process it is
//! pure redundancy: p learner threads recomputing an identical
//! O(B + misses·log p + p log p) plan every step puts O(p·B) sampler work
//! on the step critical path. The [`PartitionPlanner`] moves that work to
//! one dedicated background thread per job:
//!
//! * the planner computes each step's partition exactly once, staying up
//!   to `lead` steps ahead of training (the same pipelining idea as the
//!   loader's prefetch window), and publishes immutable [`Arc<StepPlan>`]s;
//! * learner threads `get(epoch, step)` a shared plan — a lock-light
//!   hand-off that in steady state finds the plan already published;
//! * a [`StepPlan`] stores all assignments in a single flat arena
//!   (`Vec<u32>` + per-learner offsets + run-length-encoded provenance)
//!   instead of `Vec<Vec<(u32, Provenance)>>`, so each learner's share is
//!   a zero-clone `&[u32]` slice of one allocation;
//! * the epoch permutation is built once per process and shared as an
//!   [`Arc<EpochPlan>`] (previously each learner materialized its own
//!   full-dataset copy);
//! * [`LocStats`] (balance-move counts etc.) fall out of planning as a
//!   byproduct, killing the coordinator's old duplicate
//!   `loc_partition` recompute for stats.
//!
//! DESIGN.md §8 documents the lifecycle and why per-process planning is
//! sound here while the paper's per-node planning remains the model in
//! `sim/`.

use super::{
    reg_partition_range, EpochPlan, GlobalShuffler, LocAssignment, LocStats,
    Provenance,
};
use crate::balance::{self, Transfer};
use crate::cache::CacheDirectory;
use crate::metrics::{PlannerCounters, PlannerSnapshot};
use crate::fault::{StallError, StallKind};
use anyhow::{bail, ensure, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Which partitioning scheme a plan was computed under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKind {
    /// Even contiguous slices of the global mini-batch (Fig. 4).
    Reg,
    /// Locality-aware claims + Algorithm 1 balancing (Fig. 5, §V-A).
    Loc,
}

/// The scheme the planner runs for one epoch (the coordinator plans Reg
/// during the Loc population epoch, Loc afterwards).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EpochScheme {
    Reg,
    Loc,
}

/// One run-length-encoded provenance span over the assignment arena:
/// arena positions `[prev_run.end, end)` all carry `prov`. Loc claims are
/// naturally runny (a learner's local hits, then its storage fills, then
/// balanced-in tails), so this is far denser than one tag per sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProvRun {
    /// Exclusive end position of the run in the arena.
    pub end: u32,
    pub prov: Provenance,
}

/// One step's partition for *all* learners, in a single flat arena.
///
/// Learner `j`'s share is the contiguous slice
/// `ids[offsets[j]..offsets[j+1]]` — callers borrow it zero-clone via
/// [`StepPlan::learner_ids`]. Provenance is run-length encoded over the
/// same positions. Immutable once published; shared as `Arc<StepPlan>`.
#[derive(Debug)]
pub struct StepPlan {
    pub epoch: u64,
    pub step: u64,
    pub kind: PlanKind,
    /// Partition statistics (zeros for Reg plans) — the coordinator reads
    /// `stats.balance_moves` here instead of re-partitioning.
    pub stats: LocStats,
    ids: Vec<u32>,
    /// `p + 1` fenceposts into `ids`.
    offsets: Vec<u32>,
    /// RLE provenance covering the whole arena (empty iff the arena is).
    prov_runs: Vec<ProvRun>,
}

impl StepPlan {
    /// Number of learners this plan partitions across.
    pub fn p(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total samples in the plan (the global mini-batch size).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Learner `j`'s arena range.
    pub fn learner_range(&self, j: usize) -> std::ops::Range<usize> {
        self.offsets[j] as usize..self.offsets[j + 1] as usize
    }

    /// Learner `j`'s sample ids — a zero-clone slice of the shared arena.
    pub fn learner_ids(&self, j: usize) -> &[u32] {
        &self.ids[self.learner_range(j)]
    }

    /// Provenance of the sample at arena position `i`.
    pub fn provenance_at(&self, i: usize) -> Provenance {
        debug_assert!(i < self.ids.len(), "arena position out of range");
        let k = self.prov_runs.partition_point(|r| (r.end as usize) <= i);
        self.prov_runs[k].prov
    }

    /// Learner `j`'s per-sample provenance, materialized (test/compat
    /// path; hot paths should walk [`StepPlan::prov_runs`] instead).
    pub fn learner_provenance(&self, j: usize) -> Vec<Provenance> {
        self.learner_range(j).map(|i| self.provenance_at(i)).collect()
    }

    /// The raw provenance runs.
    pub fn prov_runs(&self) -> &[ProvRun] {
        &self.prov_runs
    }

    /// Heap bytes held by the plan arena (occupancy metric for benches).
    pub fn arena_bytes(&self) -> usize {
        self.ids.len() * std::mem::size_of::<u32>()
            + self.offsets.len() * std::mem::size_of::<u32>()
            + self.prov_runs.len() * std::mem::size_of::<ProvRun>()
    }

    /// Expand back into the legacy per-learner representation (tests and
    /// equivalence checks against `loc_partition`).
    pub fn to_loc_assignments(&self) -> Vec<LocAssignment> {
        (0..self.p())
            .map(|j| LocAssignment {
                sample_ids: self.learner_ids(j).to_vec(),
                provenance: self.learner_provenance(j),
            })
            .collect()
    }

    /// Plan one step under **Reg**: even contiguous slices, by offset math
    /// over a single copy of the batch (no per-learner allocation).
    /// Identical to [`super::reg_partition`] output.
    pub fn plan_reg(epoch: u64, step: u64, batch: &[u32], p: usize) -> StepPlan {
        assert!(p > 0);
        let mut offsets = Vec::with_capacity(p + 1);
        offsets.push(0u32);
        for j in 0..p {
            offsets.push(reg_partition_range(batch.len(), p, j).end as u32);
        }
        let prov_runs = if batch.is_empty() {
            Vec::new()
        } else {
            // Reg provenance is not meaningful (the fetch path decides the
            // byte source); tag the whole arena Storage for uniformity.
            vec![ProvRun { end: batch.len() as u32, prov: Provenance::Storage }]
        };
        StepPlan {
            epoch,
            step,
            kind: PlanKind::Reg,
            stats: LocStats::default(),
            ids: batch.to_vec(),
            offsets,
            prov_runs,
        }
    }

    /// Plan one step under **Loc**. Bit-identical to
    /// [`super::loc_partition`] (assignments, provenance and stats) but
    /// with the least-loaded miss assignment on a binary heap —
    /// O(misses·log p) instead of the reference's O(misses·p) scan.
    pub fn plan_loc(
        epoch: u64,
        step: u64,
        batch: &[u32],
        dir: &CacheDirectory,
        p: usize,
    ) -> StepPlan {
        PlanScratch::default().plan_loc(epoch, step, batch, dir, p, None)
    }

    /// As [`StepPlan::plan_loc`], balancing toward
    /// [`crate::balance::weighted_targets`] under per-learner capacity
    /// weights instead of the uniform split (DESIGN.md §11 — straggler
    /// mitigation). `None` weights are exactly `plan_loc`.
    pub fn plan_loc_weighted(
        epoch: u64,
        step: u64,
        batch: &[u32],
        dir: &CacheDirectory,
        p: usize,
        weights: Option<&[f64]>,
    ) -> StepPlan {
        PlanScratch::default().plan_loc(epoch, step, batch, dir, p, weights)
    }
}

/// Reusable working memory for Loc planning: the planner thread plans
/// hundreds of steps per epoch; steady state allocates only the published
/// arena, never the scratch.
#[derive(Default)]
struct PlanScratch {
    claims: Vec<Vec<(u32, Provenance)>>,
    misses: Vec<u32>,
    loads: Vec<u64>,
    heap: BinaryHeap<Reverse<(usize, usize)>>,
    schedule: Vec<Transfer>,
}

impl PlanScratch {
    fn plan_loc(
        &mut self,
        epoch: u64,
        step: u64,
        batch: &[u32],
        dir: &CacheDirectory,
        p: usize,
        weights: Option<&[f64]>,
    ) -> StepPlan {
        assert!(p > 0);
        if self.claims.len() != p {
            self.claims.clear();
            self.claims.resize_with(p, Vec::new);
        }
        for c in &mut self.claims {
            c.clear();
        }
        self.misses.clear();

        // Step 1: cache owners claim their samples (same replicated
        // directory on every learner — no communication).
        for &s in batch {
            match dir.owner(s) {
                Some(owner) => {
                    debug_assert!(owner < p, "directory owner out of range");
                    self.claims[owner].push((s, Provenance::LocalCache));
                }
                None => self.misses.push(s),
            }
        }
        let mut stats = LocStats {
            local_hits: batch.len() - self.misses.len(),
            storage_misses: self.misses.len(),
            ..Default::default()
        };

        // Step 2: each miss to the least-loaded learner. A binary heap of
        // (load, learner) with every learner present exactly once pops the
        // same (len, j)-minimum as the reference's linear scan — ties
        // break on learner index — in O(log p) per miss.
        self.heap.clear();
        for (j, c) in self.claims.iter().enumerate() {
            self.heap.push(Reverse((c.len(), j)));
        }
        let misses = std::mem::take(&mut self.misses);
        for &s in &misses {
            let Reverse((load, j)) =
                self.heap.pop().expect("heap holds every learner");
            self.claims[j].push((s, Provenance::Storage));
            self.heap.push(Reverse((load + 1, j)));
        }
        self.misses = misses; // keep the capacity for the next step

        // Step 3: Algorithm 1 balancing, into the reused schedule buffer.
        // With capacity weights present (straggler mitigation) the targets
        // shift toward the healthy learners; the matching is unchanged.
        self.loads.clear();
        for c in &self.claims {
            self.loads.push(c.len() as u64);
        }
        let mut schedule = std::mem::take(&mut self.schedule);
        match weights {
            Some(w) => {
                let tgt = balance::weighted_targets(&self.loads, w);
                balance::balance_to_targets_into(
                    &self.loads,
                    &tgt,
                    &mut schedule,
                );
            }
            None => balance::balance_into(&self.loads, &mut schedule),
        }
        for t in &schedule {
            for _ in 0..t.amount {
                let (s, prov) =
                    self.claims[t.from].pop().expect("surplus underflow");
                // A sample that was going to be read from storage anyway
                // keeps Storage provenance (the receiver reads it); cached
                // samples become remote-cache transfers.
                let new_prov = match prov {
                    Provenance::Storage => Provenance::Storage,
                    _ => {
                        stats.balance_moves += 1;
                        Provenance::RemoteCache { from: t.from }
                    }
                };
                self.claims[t.to].push((s, new_prov));
            }
        }
        self.schedule = schedule;

        // Flatten into the published arena: learners contiguous, RLE
        // provenance over the same positions.
        let total = batch.len();
        let mut ids = Vec::with_capacity(total);
        let mut offsets = Vec::with_capacity(p + 1);
        let mut prov_runs: Vec<ProvRun> = Vec::new();
        offsets.push(0u32);
        for c in &self.claims {
            for &(s, prov) in c.iter() {
                ids.push(s);
                match prov_runs.last_mut() {
                    Some(run) if run.prov == prov => run.end = ids.len() as u32,
                    _ => prov_runs
                        .push(ProvRun { end: ids.len() as u32, prov }),
                }
            }
            offsets.push(ids.len() as u32);
        }
        debug_assert_eq!(ids.len(), total, "arena must cover the batch");
        StepPlan {
            epoch,
            step,
            kind: PlanKind::Loc,
            stats,
            ids,
            offsets,
            prov_runs,
        }
    }
}

/// Planner tuning.
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    /// Learners the partition splits across.
    pub p: usize,
    /// Global mini-batch size (`p × local_batch`).
    pub global_batch: usize,
    /// How many steps ahead of the fully-consumed frontier the planner
    /// runs (mirrors the loader's `prefetch_batches`).
    pub lead: usize,
    /// How many `get` calls retire a step from the hand-off board
    /// (the coordinator passes `p`: every learner takes each plan once).
    pub consumers: usize,
    /// Keep a trailing partial batch (see [`EpochPlan::with_partial`]).
    pub keep_partial: bool,
}

/// Per-epoch publication state on the hand-off board.
struct EpochState {
    epoch: u64,
    scheme: EpochScheme,
    eplan: Arc<EpochPlan>,
    steps: u64,
    published: HashMap<u64, Arc<StepPlan>>,
    taken: HashMap<u64, usize>,
    retired: Vec<bool>,
    /// Next step the planner thread will publish.
    next_publish: u64,
    /// Lowest step not yet retired by all consumers.
    floor: u64,
    arena_bytes_live: u64,
}

impl EpochState {
    fn new(epoch: u64, scheme: EpochScheme, eplan: Arc<EpochPlan>) -> EpochState {
        let steps = eplan.steps() as u64;
        EpochState {
            epoch,
            scheme,
            eplan,
            steps,
            published: HashMap::new(),
            taken: HashMap::new(),
            retired: vec![false; steps as usize],
            next_publish: 0,
            floor: 0,
            arena_bytes_live: 0,
        }
    }

    /// Hand out the published plan for `step`, retiring it from the board
    /// after the last consumer (the `Arc` keeps it alive for holders).
    /// Returns `(plan, retired)`; `None` if not yet published.
    fn take(
        &mut self,
        step: u64,
        consumers: usize,
    ) -> Option<(Arc<StepPlan>, bool)> {
        let plan = Arc::clone(self.published.get(&step)?);
        let taken = self.taken.entry(step).or_insert(0);
        *taken += 1;
        if *taken < consumers {
            return Some((plan, false));
        }
        self.taken.remove(&step);
        self.published.remove(&step);
        self.retired[step as usize] = true;
        self.arena_bytes_live = self
            .arena_bytes_live
            .saturating_sub(plan.arena_bytes() as u64);
        while (self.floor as usize) < self.retired.len()
            && self.retired[self.floor as usize]
        {
            self.floor += 1;
        }
        Some((plan, true))
    }
}

struct Board {
    state: Option<EpochState>,
    pending: Option<(u64, EpochScheme)>,
    closed: bool,
}

struct Shared {
    board: Mutex<Board>,
    cv: Condvar,
    counters: PlannerCounters,
    directory: Arc<CacheDirectory>,
    shuffler: GlobalShuffler,
    cfg: PlannerConfig,
    /// Advisory per-learner capacity weights (DESIGN.md §11). `None`
    /// means uniform targets; the straggler monitor amends this via
    /// [`PartitionPlanner::amend_weights`] and all subsequently computed
    /// Loc plans balance toward the weighted targets.
    weights: Mutex<Option<Vec<f64>>>,
}

/// One planner per job: a dedicated background thread computes each
/// step's partition once per process and publishes immutable
/// [`Arc<StepPlan>`]s that all learner threads consume.
pub struct PartitionPlanner {
    shared: Arc<Shared>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PartitionPlanner {
    pub fn spawn(
        cfg: PlannerConfig,
        shuffler: GlobalShuffler,
        directory: Arc<CacheDirectory>,
    ) -> PartitionPlanner {
        assert!(cfg.p > 0, "planner needs at least one learner");
        assert!(cfg.consumers > 0, "planner needs at least one consumer");
        assert!(cfg.global_batch > 0, "global batch must be positive");
        let shared = Arc::new(Shared {
            board: Mutex::new(Board {
                state: None,
                pending: None,
                closed: false,
            }),
            cv: Condvar::new(),
            counters: PlannerCounters::new(),
            directory,
            shuffler,
            cfg,
            weights: Mutex::new(None),
        });
        let thread_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("dlio-planner".into())
            .spawn(move || planner_thread(thread_shared))
            .expect("spawn partition planner");
        PartitionPlanner { shared, handle: Some(handle) }
    }

    /// Start planning `epoch` under `scheme`. Called once per epoch by a
    /// single thread (the coordinator uses learner 0, after the epoch
    /// barrier — so for Loc epochs the directory is already frozen).
    pub fn begin_epoch(&self, epoch: u64, scheme: EpochScheme) {
        let mut board = self.shared.board.lock().unwrap();
        assert!(
            board.pending.is_none(),
            "begin_epoch called before the previous request was planned"
        );
        board.pending = Some((epoch, scheme));
        drop(board);
        self.shared.cv.notify_all();
    }

    /// The shared epoch permutation — one `Arc<EpochPlan>` per epoch per
    /// process (learners no longer materialize private copies). Blocks
    /// until the planner has built it.
    pub fn epoch_plan(&self, epoch: u64) -> Result<Arc<EpochPlan>> {
        self.epoch_plan_deadline(epoch, None)
    }

    /// [`epoch_plan`] with a bounded wait: a planner thread wedged behind
    /// a dead dependency surfaces as a typed
    /// [`StallError`](crate::fault::StallError)-rooted error within
    /// `deadline` instead of hanging the epoch kickoff.
    ///
    /// [`epoch_plan`]: PartitionPlanner::epoch_plan
    pub fn epoch_plan_deadline(
        &self,
        epoch: u64,
        deadline: Option<Duration>,
    ) -> Result<Arc<EpochPlan>> {
        let t0 = Instant::now();
        let mut board = self.shared.board.lock().unwrap();
        loop {
            ensure!(!board.closed, "partition planner closed");
            if let Some(st) = &board.state {
                if st.epoch == epoch {
                    return Ok(Arc::clone(&st.eplan));
                }
                ensure!(
                    st.epoch < epoch,
                    "epoch {epoch} plan requested after epoch {} began",
                    st.epoch
                );
            }
            board = match deadline {
                None => self.shared.cv.wait(board).unwrap(),
                Some(budget) => {
                    let waited = t0.elapsed();
                    if waited >= budget {
                        return Err(StallError {
                            kind: StallKind::Plan,
                            waited,
                            deadline: budget,
                        }
                        .into());
                    }
                    self.shared
                        .cv
                        .wait_timeout(board, budget - waited)
                        .unwrap()
                        .0
                }
            };
        }
    }

    /// Take the shared plan for `(epoch, step)`. In steady state the plan
    /// is already published and this is a map lookup under one short lock;
    /// each step is retired from the board after `consumers` takes (the
    /// `Arc` keeps it alive for whoever still holds it).
    ///
    /// Requesting a step the board has already retired — every consumer
    /// took it once and someone is asking *again*, the legacy
    /// double-consume pattern — is served correctly by recomputing the
    /// partition inline, but metered in `critical_path_recomputes`: that
    /// is partition work on the calling thread, exactly what the planner
    /// exists to prevent, and benches/CI fail if it ever goes nonzero.
    pub fn get(&self, epoch: u64, step: u64) -> Result<Arc<StepPlan>> {
        self.get_deadline(epoch, step, None)
    }

    /// [`get`] with a bounded wait: if the step's plan has not been
    /// published within `deadline`, return a typed
    /// [`StallError`](crate::fault::StallError)-rooted error instead of
    /// blocking the training step indefinitely behind a wedged planner
    /// (or a peer that stopped retiring plans). `None` waits forever.
    ///
    /// [`get`]: PartitionPlanner::get
    pub fn get_deadline(
        &self,
        epoch: u64,
        step: u64,
        deadline: Option<Duration>,
    ) -> Result<Arc<StepPlan>> {
        enum Served {
            Published(Arc<StepPlan>, bool),
            Retired(Arc<EpochPlan>, EpochScheme),
        }
        let shared = &self.shared;
        let mut waited: Option<Instant> = None;
        let mut board = shared.board.lock().unwrap();
        let served = loop {
            ensure!(!board.closed, "partition planner closed");
            if let Some(st) = board.state.as_mut() {
                if st.epoch > epoch {
                    bail!(
                        "plan for epoch {epoch} step {step} requested after \
                         epoch {} began",
                        st.epoch
                    );
                }
                if st.epoch == epoch {
                    ensure!(
                        step < st.steps,
                        "step {step} out of range for epoch {epoch} \
                         ({} steps)",
                        st.steps
                    );
                    if let Some((plan, retired)) =
                        st.take(step, shared.cfg.consumers)
                    {
                        break Served::Published(plan, retired);
                    }
                    if st.retired[step as usize] {
                        break Served::Retired(
                            Arc::clone(&st.eplan),
                            st.scheme,
                        );
                    }
                }
            }
            if waited.is_none() {
                waited = Some(Instant::now());
            }
            board = match deadline {
                None => shared.cv.wait(board).unwrap(),
                Some(budget) => {
                    let spent = waited.unwrap().elapsed();
                    if spent >= budget {
                        return Err(StallError {
                            kind: StallKind::Plan,
                            waited: spent,
                            deadline: budget,
                        }
                        .into());
                    }
                    shared
                        .cv
                        .wait_timeout(board, budget - spent)
                        .unwrap()
                        .0
                }
            };
        };
        drop(board);
        let c = &shared.counters;
        match waited {
            None => {
                c.gets_immediate.fetch_add(1, Ordering::Relaxed);
            }
            Some(t0) => {
                c.gets_blocked.fetch_add(1, Ordering::Relaxed);
                let ns = t0.elapsed().as_nanos() as u64;
                c.get_wait_ns.fetch_add(ns, Ordering::Relaxed);
            }
        }
        match served {
            Served::Published(plan, retired) => {
                if retired {
                    // The publish window may have opened.
                    shared.cv.notify_all();
                }
                Ok(plan)
            }
            Served::Retired(eplan, scheme) => {
                c.critical_path_recomputes.fetch_add(1, Ordering::Relaxed);
                let mb = eplan.batch(step as usize);
                let plan = match scheme {
                    EpochScheme::Reg => StepPlan::plan_reg(
                        epoch,
                        step,
                        mb.sample_ids,
                        shared.cfg.p,
                    ),
                    EpochScheme::Loc => {
                        let w = shared.weights.lock().unwrap().clone();
                        StepPlan::plan_loc_weighted(
                            epoch,
                            step,
                            mb.sample_ids,
                            &shared.directory,
                            shared.cfg.p,
                            w.as_deref(),
                        )
                    }
                };
                Ok(Arc::new(plan))
            }
        }
    }

    /// Publish amended per-learner capacity weights (DESIGN.md §11):
    /// every Loc plan computed from now on is balanced toward
    /// [`crate::balance::weighted_targets`] under `weights`, and any
    /// already-published plan that NO consumer has taken yet is
    /// recomputed off the board lock and swapped in place. Plans with at
    /// least one take are never touched — every consumer of a step must
    /// see the identical plan, so an amendment can shift future steps
    /// but never split one (the advisory-plan protocol). Returns how
    /// many published plans were replaced.
    pub fn amend_weights(&self, weights: &[f64]) -> usize {
        let shared = &self.shared;
        assert_eq!(weights.len(), shared.cfg.p, "one weight per learner");
        *shared.weights.lock().unwrap() = Some(weights.to_vec());
        // Snapshot the amendable frontier: published Loc steps nobody
        // has taken. Recompute each outside the board lock, then swap
        // only if it is STILL untaken (a racing take wins — the step
        // keeps the plan its first consumer saw).
        let (epoch, eplan, mut steps) = {
            let board = shared.board.lock().unwrap();
            let Some(st) = board.state.as_ref() else { return 0 };
            if st.scheme != EpochScheme::Loc {
                return 0;
            }
            let steps: Vec<u64> = st
                .published
                .keys()
                .copied()
                .filter(|s| st.taken.get(s).copied().unwrap_or(0) == 0)
                .collect();
            (st.epoch, Arc::clone(&st.eplan), steps)
        };
        steps.sort_unstable();
        let mut scratch = PlanScratch::default();
        let mut replaced = 0usize;
        for &s in &steps {
            let mb = eplan.batch(s as usize);
            let plan = Arc::new(scratch.plan_loc(
                epoch,
                s,
                mb.sample_ids,
                &shared.directory,
                shared.cfg.p,
                Some(weights),
            ));
            let arena = plan.arena_bytes() as u64;
            let mut board = shared.board.lock().unwrap();
            if board.closed {
                break;
            }
            if let Some(st) = board.state.as_mut() {
                // `published` membership matters: a step retired since
                // the snapshot also has no `taken` entry, and amending
                // it would resurrect a dead board slot.
                if st.epoch == epoch
                    && st.published.contains_key(&s)
                    && st.taken.get(&s).copied().unwrap_or(0) == 0
                {
                    if let Some(old) = st.published.insert(s, plan) {
                        st.arena_bytes_live = st
                            .arena_bytes_live
                            .saturating_sub(old.arena_bytes() as u64)
                            + arena;
                        replaced += 1;
                    }
                }
            }
        }
        replaced
    }

    /// Planner health/occupancy counters (lead, wait, recompute guards).
    pub fn snapshot(&self) -> PlannerSnapshot {
        self.shared.counters.snapshot()
    }

    /// Raw counters (for callers that meter deltas).
    pub fn counters(&self) -> &PlannerCounters {
        &self.shared.counters
    }

    /// Stop the background thread; blocked `get`s error out.
    pub fn close(&self) {
        let mut board = self.shared.board.lock().unwrap();
        board.closed = true;
        drop(board);
        self.shared.cv.notify_all();
    }
}

impl Drop for PartitionPlanner {
    fn drop(&mut self) {
        self.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn planner_thread(shared: Arc<Shared>) {
    let mut scratch = PlanScratch::default();
    loop {
        // Wait for the next epoch request (or shutdown).
        let (epoch, scheme) = {
            let mut board = shared.board.lock().unwrap();
            loop {
                if board.closed {
                    return;
                }
                if let Some(req) = board.pending.take() {
                    break req;
                }
                board = shared.cv.wait(board).unwrap();
            }
        };

        // Build the epoch permutation once per process and publish it.
        let eplan = Arc::new(
            EpochPlan::new(&shared.shuffler, epoch, shared.cfg.global_batch)
                .with_partial(shared.cfg.keep_partial),
        );
        shared.counters.epochs_planned.fetch_add(1, Ordering::Relaxed);
        let steps = {
            let mut board = shared.board.lock().unwrap();
            if board.closed {
                return;
            }
            let st = EpochState::new(epoch, scheme, Arc::clone(&eplan));
            let steps = st.steps;
            board.state = Some(st);
            drop(board);
            shared.cv.notify_all();
            steps
        };

        let capacity = shared.cfg.lead.max(1) as u64;
        for step in 0..steps {
            // Window gate: stay at most `lead` unretired steps ahead.
            {
                let mut board = shared.board.lock().unwrap();
                loop {
                    if board.closed {
                        return;
                    }
                    let st = board.state.as_ref().expect("epoch state set");
                    if st.next_publish < st.floor + capacity {
                        break;
                    }
                    board = shared.cv.wait(board).unwrap();
                }
            }

            // Compute OUTSIDE the lock — this is the partition work the
            // training threads no longer do.
            let mb = eplan.batch(step as usize);
            let t0 = Instant::now();
            let plan = Arc::new(match scheme {
                EpochScheme::Reg => {
                    StepPlan::plan_reg(epoch, step, mb.sample_ids, shared.cfg.p)
                }
                EpochScheme::Loc => {
                    let w = shared.weights.lock().unwrap().clone();
                    scratch.plan_loc(
                        epoch,
                        step,
                        mb.sample_ids,
                        &shared.directory,
                        shared.cfg.p,
                        w.as_deref(),
                    )
                }
            });
            let plan_ns = t0.elapsed().as_nanos() as u64;
            shared.counters.plan_ns.fetch_add(plan_ns, Ordering::Relaxed);
            let arena = plan.arena_bytes() as u64;

            let mut board = shared.board.lock().unwrap();
            if board.closed {
                return;
            }
            let c = &shared.counters;
            let st = board.state.as_mut().expect("epoch state set");
            st.published.insert(step, plan);
            st.next_publish = step + 1;
            st.arena_bytes_live += arena;
            PlannerCounters::raise_peak(&c.arena_bytes_peak, st.arena_bytes_live);
            let lead_now = st.next_publish - st.floor;
            c.plans_published.fetch_add(1, Ordering::Relaxed);
            c.lead_steps_sum.fetch_add(lead_now, Ordering::Relaxed);
            PlannerCounters::raise_peak(&c.lead_steps_peak, lead_now);
            drop(board);
            shared.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::{loc_partition, reg_partition};
    use crate::util::prop;

    fn striped_directory(n: u32, p: usize) -> CacheDirectory {
        let dir = CacheDirectory::new(n as u64);
        for s in 0..n {
            dir.set_owner(s, (s as usize) % p);
        }
        dir
    }

    #[test]
    fn plan_reg_matches_reference_partition() {
        for (len, p) in [(120usize, 8usize), (10, 4), (7, 7), (5, 9), (0, 3)] {
            let batch: Vec<u32> = (0..len as u32).map(|i| i * 3 + 1).collect();
            let plan = StepPlan::plan_reg(2, 5, &batch, p);
            let parts = reg_partition(&batch, p);
            assert_eq!(plan.p(), p);
            assert_eq!(plan.len(), len);
            assert_eq!(plan.kind, PlanKind::Reg);
            for (j, part) in parts.iter().enumerate() {
                assert_eq!(plan.learner_ids(j), &part.sample_ids[..]);
            }
        }
    }

    #[test]
    fn plan_loc_is_bit_identical_to_sequential_reference() {
        prop::check("planner == loc_partition", 120, |rng| {
            let p = 1 + rng.next_below(16) as usize;
            let n = (p as u64 * (1 + rng.next_below(50))) as u32;
            let dir = CacheDirectory::new(n as u64);
            for s in 0..n {
                if rng.next_below(8) != 0 {
                    dir.set_owner(s, rng.next_below(p as u64) as usize);
                }
            }
            let b = (1 + rng.next_below(n.max(2) as u64 / 2)) as usize;
            let mut ids: Vec<u32> = (0..n).collect();
            rng.shuffle(&mut ids);
            let batch = &ids[..b];

            let (parts, stats) = loc_partition(batch, &dir, p);
            let plan = StepPlan::plan_loc(0, 0, batch, &dir, p);
            assert_eq!(plan.kind, PlanKind::Loc);
            assert_eq!(plan.stats.local_hits, stats.local_hits);
            assert_eq!(plan.stats.storage_misses, stats.storage_misses);
            assert_eq!(plan.stats.balance_moves, stats.balance_moves);
            for (j, part) in parts.iter().enumerate() {
                assert_eq!(
                    plan.learner_ids(j),
                    &part.sample_ids[..],
                    "ids diverge for learner {j}"
                );
                assert_eq!(
                    plan.learner_provenance(j),
                    part.provenance,
                    "provenance diverges for learner {j}"
                );
            }
        });
    }

    #[test]
    fn scratch_reuse_does_not_leak_state_across_steps() {
        // Two different batches through ONE scratch must equal fresh
        // computations (stale claims/misses would corrupt the second).
        let dir = striped_directory(500, 6);
        let mut scratch = PlanScratch::default();
        let b1: Vec<u32> = (0..120).map(|i| (i * 3) % 500).collect();
        let b2: Vec<u32> = (0..90).map(|i| (i * 7 + 1) % 500).collect();
        let a1 = scratch.plan_loc(0, 0, &b1, &dir, 6, None);
        let a2 = scratch.plan_loc(0, 1, &b2, &dir, 6, None);
        let f1 = StepPlan::plan_loc(0, 0, &b1, &dir, 6);
        let f2 = StepPlan::plan_loc(0, 1, &b2, &dir, 6);
        for j in 0..6 {
            assert_eq!(a1.learner_ids(j), f1.learner_ids(j));
            assert_eq!(a2.learner_ids(j), f2.learner_ids(j));
            assert_eq!(a2.learner_provenance(j), f2.learner_provenance(j));
        }
        // Scratch with a different p afterwards still works.
        let a3 = scratch.plan_loc(0, 2, &b1, &dir, 3, None);
        let f3 = StepPlan::plan_loc(0, 2, &b1, &dir, 3);
        for j in 0..3 {
            assert_eq!(a3.learner_ids(j), f3.learner_ids(j));
        }
    }

    #[test]
    fn prov_runs_cover_arena_and_compress() {
        let dir = striped_directory(1000, 5);
        let batch: Vec<u32> = (0..200).collect();
        let plan = StepPlan::plan_loc(0, 0, &batch, &dir, 5);
        let runs = plan.prov_runs();
        assert!(!runs.is_empty());
        assert_eq!(runs.last().unwrap().end as usize, plan.len());
        let mut prev = 0u32;
        for r in runs {
            assert!(r.end > prev, "runs must advance");
            prev = r.end;
        }
        // All-local batch: far fewer runs than samples.
        assert!(
            runs.len() <= plan.p() + plan.stats.balance_moves + 1,
            "runs should compress: {} runs for {} samples",
            runs.len(),
            plan.len()
        );
    }

    #[test]
    fn arena_bytes_tracks_payload() {
        let batch: Vec<u32> = (0..64).collect();
        let plan = StepPlan::plan_reg(0, 0, &batch, 4);
        assert!(plan.arena_bytes() >= 64 * 4 + 5 * 4);
    }

    fn direct_plan(
        scheme: EpochScheme,
        epoch: u64,
        s: u64,
        batch: &[u32],
        dir: &CacheDirectory,
        p: usize,
    ) -> StepPlan {
        match scheme {
            EpochScheme::Reg => StepPlan::plan_reg(epoch, s, batch, p),
            EpochScheme::Loc => StepPlan::plan_loc(epoch, s, batch, dir, p),
        }
    }

    #[test]
    fn pipeline_publishes_every_step_once_and_matches_direct() {
        let p = 3usize;
        let n = 600u64;
        let dir = Arc::new(striped_directory(n as u32, p));
        let shuffler = GlobalShuffler::new(77, n);
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p,
                global_batch: 60,
                lead: 2,
                consumers: p,
                keep_partial: false,
            },
            shuffler.clone(),
            Arc::clone(&dir),
        );
        for (epoch, scheme) in
            [(0u64, EpochScheme::Reg), (1, EpochScheme::Loc)]
        {
            planner.begin_epoch(epoch, scheme);
            let eplan = planner.epoch_plan(epoch).unwrap();
            assert_eq!(eplan.steps(), 10);
            // p learner threads each take every step once, in order.
            std::thread::scope(|scope| {
                for j in 0..p {
                    let planner = &planner;
                    let eplan = Arc::clone(&eplan);
                    let dir = Arc::clone(&dir);
                    scope.spawn(move || {
                        for s in 0..eplan.steps() as u64 {
                            let plan = planner.get(epoch, s).unwrap();
                            assert_eq!(plan.epoch, epoch);
                            assert_eq!(plan.step, s);
                            let mb = eplan.batch(s as usize);
                            let want = direct_plan(scheme, epoch, s, mb.sample_ids, &dir, p);
                            assert_eq!(
                                plan.learner_ids(j),
                                want.learner_ids(j),
                                "epoch {epoch} step {s} learner {j}"
                            );
                        }
                    });
                }
            });
        }
        let snap = planner.snapshot();
        assert_eq!(snap.plans_published, 20, "10 steps x 2 epochs, each once");
        assert_eq!(snap.epochs_planned, 2);
        assert_eq!(snap.critical_path_recomputes, 0);
        assert!(
            snap.lead_steps_peak <= 2 + 1,
            "lead window must bound run-ahead: {}",
            snap.lead_steps_peak
        );
        assert!(snap.arena_bytes_peak > 0);
    }

    #[test]
    fn epoch_plan_is_shared_not_copied() {
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p: 2,
                global_batch: 32,
                lead: 4,
                consumers: 1,
                keep_partial: false,
            },
            GlobalShuffler::new(5, 128),
            Arc::new(CacheDirectory::new(128)),
        );
        planner.begin_epoch(0, EpochScheme::Reg);
        let a = planner.epoch_plan(0).unwrap();
        let b = planner.epoch_plan(0).unwrap();
        assert!(
            Arc::ptr_eq(&a, &b),
            "learners must share one epoch permutation"
        );
    }

    #[test]
    fn close_unblocks_waiters_with_error() {
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p: 2,
                global_batch: 16,
                lead: 1,
                consumers: 2,
                keep_partial: false,
            },
            GlobalShuffler::new(1, 64),
            Arc::new(CacheDirectory::new(64)),
        );
        // No begin_epoch: a get would block forever without close.
        std::thread::scope(|scope| {
            let h = scope.spawn(|| planner.get(0, 0));
            std::thread::sleep(std::time::Duration::from_millis(20));
            planner.close();
            assert!(h.join().unwrap().is_err());
        });
        assert!(planner.epoch_plan(0).is_err());
    }

    #[test]
    fn over_consumed_step_recomputes_inline_and_is_metered() {
        // A step the board already retired (everyone took it once) can
        // still be served — by recomputing on the CALLING thread. That is
        // the legacy per-step double-consume pattern; the counter the
        // benches/CI gate on must tick.
        let p = 2usize;
        let dir = Arc::new(striped_directory(256, p));
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p,
                global_batch: 32,
                lead: 2,
                consumers: 1,
                keep_partial: false,
            },
            GlobalShuffler::new(8, 256),
            Arc::clone(&dir),
        );
        planner.begin_epoch(1, EpochScheme::Loc);
        let first = planner.get(1, 0).unwrap();
        assert_eq!(planner.snapshot().critical_path_recomputes, 0);
        let again = planner.get(1, 0).unwrap();
        assert_eq!(
            planner.snapshot().critical_path_recomputes,
            1,
            "double-consume must be metered as on-critical-path work"
        );
        assert!(!Arc::ptr_eq(&first, &again), "recomputed, not cached");
        for j in 0..p {
            assert_eq!(first.learner_ids(j), again.learner_ids(j));
            assert_eq!(
                first.learner_provenance(j),
                again.learner_provenance(j)
            );
        }
    }

    #[test]
    fn weighted_plan_shifts_load_toward_healthy_learners() {
        let p = 3usize;
        let dir = striped_directory(240, p);
        let batch: Vec<u32> = (0..60).collect();
        let uniform = StepPlan::plan_loc(0, 0, &batch, &dir, p);
        // Weights of None reproduce plan_loc exactly.
        let same =
            StepPlan::plan_loc_weighted(0, 0, &batch, &dir, p, None);
        for j in 0..p {
            assert_eq!(uniform.learner_ids(j), same.learner_ids(j));
        }
        // A dead learner (weight 0) ends up with an empty share; the
        // survivors split its load.
        let w = [1.0, 1.0, 0.0];
        let plan =
            StepPlan::plan_loc_weighted(0, 0, &batch, &dir, p, Some(&w));
        assert_eq!(plan.learner_ids(2).len(), 0, "dead learner keeps load");
        assert_eq!(
            plan.learner_ids(0).len() + plan.learner_ids(1).len(),
            60,
            "total conserved"
        );
        assert_eq!(plan.len(), 60);
    }

    #[test]
    fn amend_weights_reroutes_published_and_future_plans() {
        let p = 3usize;
        let dir = Arc::new(striped_directory(240, p));
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p,
                global_batch: 60,
                lead: 3,
                consumers: 1,
                keep_partial: false,
            },
            GlobalShuffler::new(21, 240),
            Arc::clone(&dir),
        );
        planner.begin_epoch(0, EpochScheme::Loc);
        let eplan = planner.epoch_plan(0).unwrap();
        assert_eq!(eplan.steps(), 4);
        // Let the planner fill its whole lead window: it then blocks at
        // the window gate with NO plan in flight, so every published
        // plan is amendable and every later one sees the new weights.
        while planner.snapshot().plans_published < 3 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let replaced = planner.amend_weights(&[1.0, 1.0, 0.0]);
        assert_eq!(replaced, 3, "all published untaken plans amended");
        // Every step seen after the amendment — replaced or computed
        // fresh under the new weights — routes around learner 2.
        for s in 0..eplan.steps() as u64 {
            let plan = planner.get(0, s).unwrap();
            assert_eq!(
                plan.learner_ids(2).len(),
                0,
                "step {s} still loads the drained learner"
            );
            assert_eq!(plan.len(), 60, "step {s} lost samples");
        }
    }

    #[test]
    fn amend_weights_never_splits_a_partially_taken_step() {
        let p = 2usize;
        let dir = Arc::new(striped_directory(128, p));
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p,
                global_batch: 32,
                lead: 2,
                consumers: 2,
                keep_partial: false,
            },
            GlobalShuffler::new(9, 128),
            Arc::clone(&dir),
        );
        planner.begin_epoch(0, EpochScheme::Loc);
        planner.epoch_plan(0).unwrap();
        // Consumer 0 takes step 0; the step is now partially taken.
        let first = planner.get(0, 0).unwrap();
        planner.amend_weights(&[1.0, 0.0]);
        // Consumer 1 must see the SAME plan object, not an amended one.
        let second = planner.get(0, 0).unwrap();
        assert!(
            Arc::ptr_eq(&first, &second),
            "amendment split a partially-taken step"
        );
        // Amendment on a Reg epoch is a no-op (nothing to reweight).
        for s in 1..4u64 {
            for _ in 0..2 {
                planner.get(0, s).unwrap();
            }
        }
        planner.begin_epoch(1, EpochScheme::Reg);
        planner.epoch_plan(1).unwrap();
        assert_eq!(planner.amend_weights(&[1.0, 1.0]), 0);
    }

    #[test]
    fn stale_epoch_request_errors_instead_of_hanging() {
        let planner = PartitionPlanner::spawn(
            PlannerConfig {
                p: 1,
                global_batch: 8,
                lead: 2,
                consumers: 1,
                keep_partial: false,
            },
            GlobalShuffler::new(3, 64),
            Arc::new(CacheDirectory::new(64)),
        );
        planner.begin_epoch(0, EpochScheme::Reg);
        let steps = planner.epoch_plan(0).unwrap().steps() as u64;
        for s in 0..steps {
            planner.get(0, s).unwrap();
        }
        planner.begin_epoch(1, EpochScheme::Reg);
        planner.epoch_plan(1).unwrap();
        assert!(planner.get(0, 0).is_err(), "epoch 0 is gone");
    }
}
