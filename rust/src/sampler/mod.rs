//! Mini-batch sampling: the global shuffler and the two partitioning
//! schemes the paper compares.
//!
//! * [`GlobalShuffler`] — every learner derives the *identical* epoch
//!   permutation from (seed, epoch), with no communication (paper §II-A
//!   step 1: "each learner acquires the same global mini-batch sequence").
//! * [`reg_partition`] — **Reg**: the conventional scheme; the global
//!   mini-batch sequence is split into even, contiguous slices (Fig. 4).
//! * [`loc_partition`] — **Loc**: the locality-aware scheme; each learner
//!   claims the samples of the global mini-batch that its local cache
//!   holds, cache misses are assigned to the least-loaded learners, and
//!   Algorithm 1 then balances the loads (Fig. 5, §V-A).
//! * [`PartitionPlanner`] — the shared epoch-partition planner: one
//!   background thread per process computes each step's partition once
//!   (into a flat-arena [`StepPlan`]) and all learner threads consume it,
//!   taking the O(p·B) redundant sampler work off the step critical path.

pub mod plan;
pub mod planner;

pub use plan::{EpochPlan, MiniBatch};
pub use planner::{
    EpochScheme, PartitionPlanner, PlanKind, PlannerConfig, ProvRun, StepPlan,
};

use crate::cache::CacheDirectory;
use crate::util::rng::Rng;

/// Derives identical epoch permutations on every learner from a shared seed.
#[derive(Clone, Debug)]
pub struct GlobalShuffler {
    seed: u64,
    n_samples: u64,
}

impl GlobalShuffler {
    pub fn new(seed: u64, n_samples: u64) -> Self {
        GlobalShuffler { seed, n_samples }
    }

    pub fn n_samples(&self) -> u64 {
        self.n_samples
    }

    /// The random permutation of all samples for `epoch`. Deterministic:
    /// every learner calling this gets byte-identical output.
    pub fn epoch_permutation(&self, epoch: u64) -> Vec<u32> {
        let mut rng = Rng::new(self.seed).substream(0xE90C).substream(epoch);
        rng.permutation(self.n_samples as usize)
    }
}

/// A learner's share of one global mini-batch: the sample ids it must load
/// and train with this step.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Assignment {
    pub sample_ids: Vec<u32>,
}

/// **Reg**: split the global sequence into contiguous, even slices.
/// When `batch.len()` is not divisible by `p`, the first `len % p`
/// learners take one extra sample (deterministic on every learner).
pub fn reg_partition(batch: &[u32], p: usize) -> Vec<Assignment> {
    assert!(p > 0);
    let base = batch.len() / p;
    let rem = batch.len() % p;
    let mut out = Vec::with_capacity(p);
    let mut cursor = 0;
    for j in 0..p {
        let take = base + usize::from(j < rem);
        out.push(Assignment {
            sample_ids: batch[cursor..cursor + take].to_vec(),
        });
        cursor += take;
    }
    debug_assert_eq!(cursor, batch.len());
    out
}

/// Learner `j`'s contiguous index range of a Reg split, by offset math
/// alone — no `Vec<Assignment>` allocation, no per-learner clone. Exactly
/// the range `reg_partition(batch, p)[j]` covers.
pub fn reg_partition_range(len: usize, p: usize, j: usize) -> std::ops::Range<usize> {
    assert!(p > 0);
    assert!(j < p, "learner {j} out of range for p={p}");
    let base = len / p;
    let rem = len % p;
    let lo = j * base + j.min(rem);
    let hi = lo + base + usize::from(j < rem);
    lo..hi
}

/// Learner `j`'s Reg share as a zero-copy slice of the global mini-batch.
pub fn reg_partition_slice(batch: &[u32], p: usize, j: usize) -> &[u32] {
    &batch[reg_partition_range(batch.len(), p, j)]
}

/// Where a Loc sample comes from, for accounting and for the loader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// In the learner's own cache.
    LocalCache,
    /// Moved from another learner's cache for load balancing.
    RemoteCache { from: usize },
    /// Not in the aggregated cache; read from the storage system.
    Storage,
}

/// A Loc assignment with provenance per sample.
#[derive(Clone, Debug, Default)]
pub struct LocAssignment {
    pub sample_ids: Vec<u32>,
    pub provenance: Vec<Provenance>,
}

impl LocAssignment {
    pub fn len(&self) -> usize {
        self.sample_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sample_ids.is_empty()
    }

    /// Bytes-free view used by the coordinator.
    pub fn to_assignment(&self) -> Assignment {
        Assignment { sample_ids: self.sample_ids.clone() }
    }
}

/// Statistics of one Loc partition step (feeds Fig. 6 and EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, Default)]
pub struct LocStats {
    pub local_hits: usize,
    pub balance_moves: usize,
    pub storage_misses: usize,
}

impl LocStats {
    /// The paper's "imbalance traffic volume percentage": moved samples
    /// over mini-batch size.
    pub fn imbalance_pct(&self, batch_len: usize) -> f64 {
        100.0 * self.balance_moves as f64 / batch_len.max(1) as f64
    }
}

/// **Loc**: locality-aware partition of one global mini-batch.
///
/// 1. Each sample is claimed by the learner whose cache holds it
///    (everyone consults the same replicated [`CacheDirectory`], so no
///    communication is needed).
/// 2. Samples absent from the aggregated cache are assigned to learners
///    with the smallest claim (they will be read from storage — this also
///    helps balance).
/// 3. [`crate::balance::balance`] computes the minimal transfer schedule;
///    overloaded learners hand their *latest-claimed* samples to
///    underloaded ones (deterministic, identical on every learner).
pub fn loc_partition(
    batch: &[u32],
    dir: &CacheDirectory,
    p: usize,
) -> (Vec<LocAssignment>, LocStats) {
    assert!(p > 0);
    let mut claims: Vec<Vec<(u32, Provenance)>> = vec![Vec::new(); p];
    let mut misses: Vec<u32> = Vec::new();
    for &s in batch {
        match dir.owner(s) {
            Some(owner) => {
                debug_assert!(owner < p, "directory owner out of range");
                claims[owner].push((s, Provenance::LocalCache));
            }
            None => misses.push(s),
        }
    }
    let mut stats = LocStats {
        local_hits: batch.len() - misses.len(),
        ..Default::default()
    };
    stats.storage_misses = misses.len();

    // Step 2: give each miss to the currently least-loaded learner.
    // (Deterministic: ties break on learner index.)
    for s in misses {
        let (j, _) = claims
            .iter()
            .enumerate()
            .min_by_key(|(j, c)| (c.len(), *j))
            .unwrap();
        claims[j].push((s, Provenance::Storage));
    }

    // Step 3: balance with Algorithm 1.
    let loads: Vec<u64> = claims.iter().map(|c| c.len() as u64).collect();
    let schedule = crate::balance::balance(&loads);
    for t in &schedule {
        let from = t.from;
        let to = t.to;
        for _ in 0..t.amount {
            let (s, prov) = claims[from].pop().expect("surplus underflow");
            // A sample that was going to be read from storage anyway keeps
            // its Storage provenance (the receiving learner reads it);
            // cached samples become remote-cache transfers.
            let new_prov = match prov {
                Provenance::Storage => Provenance::Storage,
                _ => {
                    stats.balance_moves += 1;
                    Provenance::RemoteCache { from }
                }
            };
            claims[to].push((s, new_prov));
        }
    }

    let out = claims
        .into_iter()
        .map(|c| {
            let (sample_ids, provenance) = c.into_iter().unzip();
            LocAssignment { sample_ids, provenance }
        })
        .collect();
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheDirectory;
    use crate::util::prop;

    #[test]
    fn shuffler_identical_across_learners() {
        let a = GlobalShuffler::new(99, 1000);
        let b = GlobalShuffler::new(99, 1000);
        assert_eq!(a.epoch_permutation(0), b.epoch_permutation(0));
        assert_eq!(a.epoch_permutation(7), b.epoch_permutation(7));
        assert_ne!(a.epoch_permutation(0), a.epoch_permutation(1));
    }

    #[test]
    fn shuffler_permutation_is_bijection() {
        let s = GlobalShuffler::new(5, 500);
        let p = s.epoch_permutation(3);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        let expect: Vec<u32> = (0..500).collect();
        assert_eq!(sorted, expect);
    }

    #[test]
    fn reg_partition_even_and_covering() {
        let batch: Vec<u32> = (0..120).collect();
        let parts = reg_partition(&batch, 8);
        assert_eq!(parts.len(), 8);
        let mut all: Vec<u32> = Vec::new();
        for p in &parts {
            assert_eq!(p.sample_ids.len(), 15);
            all.extend(&p.sample_ids);
        }
        assert_eq!(all, batch);
    }

    #[test]
    fn reg_partition_remainder_spread() {
        let batch: Vec<u32> = (0..10).collect();
        let parts = reg_partition(&batch, 4);
        let sizes: Vec<usize> =
            parts.iter().map(|a| a.sample_ids.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn reg_partition_slice_matches_reg_partition() {
        prop::check("reg slice equals allocated partition", 120, |rng| {
            let p = 1 + rng.next_below(12) as usize;
            let len = rng.next_below(200) as usize + p; // at least p samples
            let batch: Vec<u32> = (0..len as u32).map(|i| i * 7).collect();
            let parts = reg_partition(&batch, p);
            let mut cursor = 0usize;
            for (j, part) in parts.iter().enumerate() {
                let r = reg_partition_range(len, p, j);
                assert_eq!(r.start, cursor, "ranges must tile the batch");
                assert_eq!(
                    reg_partition_slice(&batch, p, j),
                    &part.sample_ids[..],
                    "slice j={j} diverges from reg_partition"
                );
                cursor = r.end;
            }
            assert_eq!(cursor, len);
        });
    }

    fn striped_directory(n: u32, p: usize) -> CacheDirectory {
        let dir = CacheDirectory::new(n as u64);
        for s in 0..n {
            dir.set_owner(s, (s as usize) % p);
        }
        dir
    }

    #[test]
    fn loc_partition_covers_batch_exactly_once() {
        let dir = striped_directory(1000, 7);
        let batch: Vec<u32> = (0..350).map(|i| (i * 3) % 1000).collect();
        let (parts, stats) = loc_partition(&batch, &dir, 7);
        let mut all: Vec<u32> =
            parts.iter().flat_map(|a| a.sample_ids.clone()).collect();
        all.sort_unstable();
        let mut want = batch.clone();
        want.sort_unstable();
        assert_eq!(all, want);
        assert_eq!(stats.local_hits + stats.storage_misses, batch.len());
    }

    #[test]
    fn loc_partition_balances_loads() {
        let dir = striped_directory(997, 5);
        let batch: Vec<u32> = (0..100).collect();
        let (parts, _) = loc_partition(&batch, &dir, 5);
        for p in &parts {
            assert_eq!(p.len(), 20);
        }
    }

    #[test]
    fn loc_partition_misses_become_storage_loads() {
        // Directory covers only even ids.
        let dir = CacheDirectory::new(100);
        for s in (0..100u32).step_by(2) {
            dir.set_owner(s, (s as usize / 2) % 4);
        }
        let batch: Vec<u32> = (0..40).collect(); // half odd => 20 misses
        let (parts, stats) = loc_partition(&batch, &dir, 4);
        assert_eq!(stats.storage_misses, 20);
        assert_eq!(stats.local_hits, 20);
        let storage_count: usize = parts
            .iter()
            .flat_map(|a| &a.provenance)
            .filter(|p| matches!(p, Provenance::Storage))
            .count();
        assert_eq!(storage_count, 20);
    }

    #[test]
    fn prop_loc_partition_invariants() {
        prop::check("loc partition invariants", 150, |rng| {
            let p = 1 + rng.next_below(16) as usize;
            let n = (p as u64 * (1 + rng.next_below(50))) as u32;
            // Random directory: each sample cached on a random learner, or
            // missing with prob ~1/8.
            let dir = CacheDirectory::new(n as u64);
            for s in 0..n {
                if rng.next_below(8) != 0 {
                    dir.set_owner(s, rng.next_below(p as u64) as usize);
                }
            }
            let b = (1 + rng.next_below(n.max(2) as u64 / 2)) as usize;
            let mut ids: Vec<u32> = (0..n).collect();
            rng.shuffle(&mut ids);
            let batch = &ids[..b];
            let (parts, stats) = loc_partition(batch, &dir, p);

            // Exactly-once coverage.
            let mut all: Vec<u32> =
                parts.iter().flat_map(|a| a.sample_ids.clone()).collect();
            all.sort_unstable();
            let mut want = batch.to_vec();
            want.sort_unstable();
            assert_eq!(all, want);

            // Balanced: sizes differ by at most 1.
            let sizes: Vec<usize> = parts.iter().map(|a| a.len()).collect();
            let mn = *sizes.iter().min().unwrap();
            let mx = *sizes.iter().max().unwrap();
            assert!(mx - mn <= 1, "unbalanced: {sizes:?}");

            // Provenance counts are consistent.
            assert_eq!(stats.local_hits + stats.storage_misses, b);
            let remote: usize = parts
                .iter()
                .flat_map(|a| &a.provenance)
                .filter(|p| matches!(p, Provenance::RemoteCache { .. }))
                .count();
            assert_eq!(remote, stats.balance_moves);
        });
    }

    #[test]
    fn loc_partition_is_deterministic() {
        let dir = striped_directory(512, 6);
        let batch: Vec<u32> = (0..128).map(|i| (i * 5) % 512).collect();
        let (a, _) = loc_partition(&batch, &dir, 6);
        let (b, _) = loc_partition(&batch, &dir, 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sample_ids, y.sample_ids);
        }
    }
}
