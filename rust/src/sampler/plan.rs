//! Epoch plans: turning a global permutation into a sequence of global
//! mini-batches (paper §II-A: "a step refers to training a single
//! mini-batch, an epoch to training the whole dataset in multiple steps").

use super::GlobalShuffler;

/// One global mini-batch: the step index plus the slice of the epoch
/// permutation that all learners collectively load this step.
#[derive(Clone, Debug)]
pub struct MiniBatch<'a> {
    pub step: usize,
    pub sample_ids: &'a [u32],
}

/// The full plan for one epoch. Identical on every learner (it is a pure
/// function of the shuffler seed, epoch index and global batch size).
#[derive(Clone, Debug)]
pub struct EpochPlan {
    epoch: u64,
    global_batch: usize,
    perm: Vec<u32>,
    /// Whether a trailing partial batch is kept (`true`) or dropped
    /// (`false`, the common practice and our default — compiled batch
    /// shapes are static).
    keep_partial: bool,
}

impl EpochPlan {
    pub fn new(shuffler: &GlobalShuffler, epoch: u64, global_batch: usize) -> Self {
        assert!(global_batch > 0);
        EpochPlan {
            epoch,
            global_batch,
            perm: shuffler.epoch_permutation(epoch),
            keep_partial: false,
        }
    }

    pub fn with_partial(mut self, keep: bool) -> Self {
        self.keep_partial = keep;
        self
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn global_batch(&self) -> usize {
        self.global_batch
    }

    /// Number of steps in this epoch.
    pub fn steps(&self) -> usize {
        let full = self.perm.len() / self.global_batch;
        if self.keep_partial && self.perm.len() % self.global_batch != 0 {
            full + 1
        } else {
            full
        }
    }

    /// The `step`-th global mini-batch.
    pub fn batch(&self, step: usize) -> MiniBatch<'_> {
        assert!(step < self.steps(), "step {step} out of range");
        let lo = step * self.global_batch;
        let hi = (lo + self.global_batch).min(self.perm.len());
        MiniBatch { step, sample_ids: &self.perm[lo..hi] }
    }

    /// Iterate over all mini-batches of the epoch.
    pub fn iter(&self) -> impl Iterator<Item = MiniBatch<'_>> {
        (0..self.steps()).map(move |s| self.batch(s))
    }

    /// Total samples covered by this plan.
    pub fn covered(&self) -> usize {
        self.steps().saturating_mul(self.global_batch).min(self.perm.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_dataset_in_disjoint_batches() {
        let sh = GlobalShuffler::new(1, 1000);
        let plan = EpochPlan::new(&sh, 0, 128);
        assert_eq!(plan.steps(), 7); // 1000/128 = 7 full, partial dropped
        let mut seen = std::collections::HashSet::new();
        for mb in plan.iter() {
            assert_eq!(mb.sample_ids.len(), 128);
            for &s in mb.sample_ids {
                assert!(seen.insert(s), "sample {s} appeared twice");
            }
        }
        assert_eq!(seen.len(), 896);
    }

    #[test]
    fn keep_partial_includes_tail() {
        let sh = GlobalShuffler::new(1, 100);
        let plan = EpochPlan::new(&sh, 0, 32).with_partial(true);
        assert_eq!(plan.steps(), 4);
        assert_eq!(plan.batch(3).sample_ids.len(), 4);
        let total: usize = plan.iter().map(|b| b.sample_ids.len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn plans_identical_across_replicas() {
        let a = EpochPlan::new(&GlobalShuffler::new(9, 256), 5, 64);
        let b = EpochPlan::new(&GlobalShuffler::new(9, 256), 5, 64);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.sample_ids, y.sample_ids);
        }
    }

    #[test]
    fn different_epochs_reshuffle() {
        let sh = GlobalShuffler::new(9, 256);
        let a = EpochPlan::new(&sh, 0, 64);
        let b = EpochPlan::new(&sh, 1, 64);
        assert_ne!(a.batch(0).sample_ids, b.batch(0).sample_ids);
    }
}
