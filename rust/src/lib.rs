//! # dlio — locality-aware data loading for distributed DNN training
//!
//! Production-grade reproduction of Yang & Cong, *Accelerating Data Loading
//! in Deep Neural Network Training* (HiPC 2019). See `DESIGN.md` for the
//! system inventory and the per-figure experiment index.
//!
//! Architecture (three layers, Python never on the request path):
//!
//! * **L3 (this crate)** — the coordination contribution: global shuffler,
//!   Reg/Loc partitioners, software caches + cache directory, Algorithm 1
//!   load balancer, multi-worker prefetching loader, learner/epoch training
//!   driver, bandwidth-limited storage + interconnect substrates, a
//!   discrete-event cluster simulator, and the analytic model of §IV.
//! * **L2** — JAX model programs (`python/compile/model.py`), AOT-lowered to
//!   HLO text under `artifacts/`.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) called by L2.
//!
//! The [`runtime`] module loads the artifacts via the PJRT C API (`xla`
//! crate) and executes them from the coordinator hot path.

pub mod analytic;
pub mod balance;
pub mod bench;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod figures;
pub mod loader;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sampler;
pub mod sim;
pub mod storage;
pub mod util;
