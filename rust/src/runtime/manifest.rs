//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` lists every AOT-compiled program (HLO text
//! file + typed input/output signatures), the initial parameter binaries,
//! and the model geometry. The runtime validates every execution against
//! these signatures, so a Python-side change that isn't re-lowered fails
//! loudly instead of silently miscomputing.

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of a tensor crossing the runtime boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u8" => DType::U8,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

/// Shape + dtype + name of one program input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j
                .at(&["name"])
                .as_str()
                .context("tensor spec missing name")?
                .to_string(),
            shape: j
                .at(&["shape"])
                .as_arr()
                .context("tensor spec missing shape")?
                .iter()
                .map(|v| v.as_usize().context("non-numeric dim"))
                .collect::<Result<_>>()?,
            dtype: DType::parse(
                j.at(&["dtype"]).as_str().context("tensor spec missing dtype")?,
            )?,
        })
    }
}

/// One AOT-compiled program.
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One persisted initial-parameter tensor.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub path: PathBuf,
}

/// Model geometry (mirrors `python/compile/model.py`).
#[derive(Clone, Debug)]
pub struct Geometry {
    pub img: (usize, usize, usize),
    pub n_features: usize,
    pub n_classes: usize,
    pub batch_sizes: Vec<usize>,
    pub param_names: Vec<String>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub lowered_with: String,
    pub seed: u64,
    pub geometry: Geometry,
    pub programs: BTreeMap<String, ProgramSpec>,
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse manifest.json: {e}"))?;

        let geo = j.at(&["geometry"]);
        let img = geo.at(&["img"]).as_arr().context("geometry.img")?;
        let geometry = Geometry {
            img: (
                img[0].as_usize().context("img.h")?,
                img[1].as_usize().context("img.w")?,
                img[2].as_usize().context("img.c")?,
            ),
            n_features: geo
                .at(&["n_features"])
                .as_usize()
                .context("n_features")?,
            n_classes: geo.at(&["n_classes"]).as_usize().context("n_classes")?,
            batch_sizes: geo
                .at(&["batch_sizes"])
                .as_arr()
                .context("batch_sizes")?
                .iter()
                .map(|v| v.as_usize().context("batch size"))
                .collect::<Result<_>>()?,
            param_names: geo
                .at(&["param_names"])
                .as_arr()
                .context("param_names")?
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).context("param name")
                })
                .collect::<Result<_>>()?,
        };

        let mut programs = BTreeMap::new();
        for (name, pj) in
            j.at(&["programs"]).as_obj().context("programs")?.iter()
        {
            let inputs = pj
                .at(&["inputs"])
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("program {name} inputs"))?;
            let outputs = pj
                .at(&["outputs"])
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("program {name} outputs"))?;
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name: name.clone(),
                    hlo_path: dir
                        .join(pj.at(&["file"]).as_str().context("file")?),
                    inputs,
                    outputs,
                },
            );
        }

        let params = j
            .at(&["params"])
            .as_arr()
            .context("params")?
            .iter()
            .map(|pj| {
                Ok(ParamSpec {
                    name: pj
                        .at(&["name"])
                        .as_str()
                        .context("param name")?
                        .to_string(),
                    shape: pj
                        .at(&["shape"])
                        .as_arr()
                        .context("param shape")?
                        .iter()
                        .map(|v| v.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    path: dir.join(pj.at(&["file"]).as_str().context("file")?),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            lowered_with: j
                .at(&["lowered_with"])
                .as_str()
                .unwrap_or("unknown")
                .to_string(),
            seed: j.at(&["seed"]).as_usize().unwrap_or(0) as u64,
            geometry,
            programs,
            params,
        })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .with_context(|| format!("program {name:?} not in manifest"))
    }

    /// The largest compiled batch size ≤ `want`, or the smallest available.
    pub fn pick_batch(&self, want: usize) -> usize {
        let mut sizes = self.geometry.batch_sizes.clone();
        sizes.sort_unstable();
        sizes
            .iter()
            .rev()
            .find(|&&b| b <= want)
            .copied()
            .unwrap_or_else(|| sizes[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.geometry.img, (32, 32, 3));
        assert_eq!(m.geometry.n_features, 3072);
        assert_eq!(m.geometry.param_names.len(), 6);
        for b in &m.geometry.batch_sizes {
            for stem in ["preprocess", "grad", "train", "eval"] {
                let p = m.program(&format!("{stem}{b}")).unwrap();
                assert!(p.hlo_path.exists(), "{}", p.hlo_path.display());
                assert!(!p.inputs.is_empty());
                assert!(!p.outputs.is_empty());
            }
        }
        // grad outputs = 6 grads + loss; inputs = 6 params + x + y.
        let g = m.program("grad64").unwrap();
        assert_eq!(g.outputs.len(), 7);
        assert_eq!(g.inputs.len(), 8);
        assert_eq!(g.inputs[6].shape, vec![64, 3072]);
        assert_eq!(g.inputs[7].dtype, DType::I32);
    }

    #[test]
    fn pick_batch_rounds_down() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_batch(64), 64);
        assert_eq!(m.pick_batch(100), 64);
        assert_eq!(m.pick_batch(4096), 256);
        assert_eq!(m.pick_batch(1), 16); // smallest available
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-dlio")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::U8.size_bytes(), 1);
        assert!(DType::parse("f64").is_err());
    }
}
