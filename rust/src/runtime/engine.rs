//! The PJRT execution engine: loads HLO-text artifacts, compiles them on
//! the CPU PJRT client, and executes them from the coordinator hot path.
//!
//! Pattern follows `/opt/xla-example/load_hlo`: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per (program,
//! batch-size) variant; compilation is lazy and cached, so a process that
//! only trains at B=64 never compiles the B=256 variants.
//!
//! Thread-safety: the PJRT C API guarantees `PjRtLoadedExecutable::Execute`
//! and client operations are thread-safe; the Rust wrapper types simply
//! hold raw pointers and are not marked `Send`/`Sync`. [`Engine`] and
//! [`Program`] assert those bounds (with the PJRT contract as
//! justification) so loader workers and learner threads can execute
//! concurrently.

use super::manifest::{DType, Manifest, ProgramSpec};
// Without the `pjrt` feature the `xla` paths below resolve to the in-tree
// stub, whose entry points fail at runtime with a clear message.
#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;
use super::tensor::{Data, HostTensor};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// A compiled, executable program with its manifest signature.
pub struct Program {
    spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
    executions: AtomicU64,
    exec_ns: AtomicU64,
}

// SAFETY: PJRT executables are internally synchronized; Execute is
// documented thread-safe in the PJRT C API. The wrapper only holds an
// opaque pointer whose lifetime we manage single-ownership via Arc.
unsafe impl Send for Program {}
unsafe impl Sync for Program {}

impl Program {
    /// Execute the program. Inputs are validated against the manifest
    /// signature; outputs are converted back to [`HostTensor`]s.
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = args.iter().collect();
        self.run_refs(&refs)
    }

    /// As [`run`], but with borrowed arguments — the coordinator hot path
    /// uses this to avoid cloning ~14 MiB of parameters per step
    /// (§Perf: before/after in EXPERIMENTS.md).
    ///
    /// [`run`]: Program::run
    pub fn run_refs(&self, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        ensure!(
            args.len() == self.spec.inputs.len(),
            "{}: got {} args, expected {}",
            self.spec.name,
            args.len(),
            self.spec.inputs.len()
        );
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            arg.check(spec)
                .with_context(|| format!("program {}", self.spec.name))?;
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| to_literal(t))
            .collect::<Result<_>>()
            .with_context(|| format!("program {} inputs", self.spec.name))?;

        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("{}: execute: {e:?}", self.spec.name))?;
        self.exec_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.executions.fetch_add(1, Ordering::Relaxed);

        ensure!(!result.is_empty() && !result[0].is_empty(), "empty result");
        // aot.py lowers with return_tuple=True: one tuple buffer.
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = match root.shape() {
            Ok(xla::Shape::Tuple(_)) => root
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?,
            _ => vec![root],
        };
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: {} outputs, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&self.spec.outputs) {
            let t = from_literal(lit)
                .with_context(|| format!("output {}", spec.name))?;
            t.check(spec)
                .with_context(|| format!("program {} output", self.spec.name))?;
            out.push(t);
        }
        Ok(out)
    }

    pub fn spec(&self) -> &ProgramSpec {
        &self.spec
    }

    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    /// Mean wall-clock seconds per execution (measures the paper's V).
    pub fn mean_exec_s(&self) -> f64 {
        let n = self.executions();
        if n == 0 {
            return f64::NAN;
        }
        self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let ty = match t.dtype() {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::U8 => xla::ElementType::U8,
    };
    let bytes = t.byte_view();
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &bytes)
        .map_err(|e| anyhow::anyhow!("literal create: {e:?}"))
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("array_shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match shape.element_type() {
        xla::ElementType::F32 => Data::F32(
            lit.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
        ),
        xla::ElementType::S32 => Data::I32(
            lit.to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?,
        ),
        xla::ElementType::U8 => Data::U8(
            lit.to_vec::<u8>()
                .map_err(|e| anyhow::anyhow!("to_vec u8: {e:?}"))?,
        ),
        other => bail!("unsupported output element type {other:?}"),
    };
    Ok(HostTensor { shape: dims, data })
}

/// The runtime engine: PJRT client + lazily compiled program cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Read-mostly after warmup: learners and loader workers look
    /// programs up every step, so lookups take a shared read lock and
    /// only first-use compilation takes the write lock.
    programs: RwLock<HashMap<String, Arc<Program>>>,
}

// SAFETY: see Program. PjRtClient (CPU) is thread-safe per the PJRT C API.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Open the artifacts directory and initialize the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Engine { client, manifest, programs: RwLock::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) a program by manifest name.
    pub fn program(&self, name: &str) -> Result<Arc<Program>> {
        if let Some(p) = self.programs.read().unwrap().get(name) {
            return Ok(Arc::clone(p));
        }
        // Compile outside the lock: compilation can take seconds and other
        // programs' executions shouldn't stall behind it. A racing thread
        // may compile the same program; last insert wins (harmless).
        let spec = self.manifest.program(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.hlo_path)
            .map_err(|e| {
                anyhow::anyhow!("parse {}: {e:?}", spec.hlo_path.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let program = Arc::new(Program {
            spec,
            exe,
            executions: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
        });
        let mut cache = self.programs.write().unwrap();
        let entry = cache.entry(name.to_string()).or_insert_with(|| {
            eprintln!(
                "engine: compiled {name} in {:.2}s",
                t0.elapsed().as_secs_f64()
            );
            Arc::clone(&program)
        });
        Ok(Arc::clone(entry))
    }

    /// Load the initial parameters (He init persisted by aot.py), in the
    /// canonical `param_names` order.
    pub fn initial_params(&self) -> Result<Vec<HostTensor>> {
        self.manifest
            .params
            .iter()
            .map(|p| HostTensor::from_f32_file(&p.path, p.shape.clone()))
            .collect()
    }
}
