//! Runtime layer: PJRT client, artifact manifest, host tensors, and the
//! lazily-compiled program cache that executes the AOT-lowered JAX/Pallas
//! programs from `artifacts/` (see `python/compile/aot.py`).

pub mod engine;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
pub mod tensor;

pub use engine::{Engine, Program};
pub use manifest::{DType, Geometry, Manifest, ProgramSpec, TensorSpec};
pub use tensor::{Data, HostTensor};

use std::path::PathBuf;

/// Default artifacts directory: `$DLIO_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("DLIO_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
