//! Compile-time stand-in for the `xla` PJRT crate.
//!
//! The offline toolchain image ships the real `xla` crate; this stub lets
//! every other environment build and test the full crate without it. It
//! mirrors exactly the API surface `engine.rs` touches; every entry point
//! that would reach PJRT fails at runtime with a clear message, so
//! `Engine::load` errors out cleanly and all artifacts-dependent tests
//! (which check for `manifest.json` first) skip themselves.
//!
//! Build with `--features pjrt` (and the `xla` dependency added on images
//! that carry it) to swap in the real backend.

#![allow(dead_code)]

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT backend unavailable: dlio was built without \
                           the `pjrt` feature (offline `xla` crate)";

/// Stub error; only ever carries the "unavailable" message.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
    Unsupported,
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

pub enum Shape {
    Tuple(Vec<Shape>),
    Array(ArrayShape),
}

pub struct Literal {}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn shape(&self) -> Result<Shape, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "pjrt-unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}
