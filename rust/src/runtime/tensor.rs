//! Host-side tensors crossing the PJRT boundary.
//!
//! [`HostTensor`] is the typed buffer the coordinator manipulates (batches,
//! parameters, gradients); conversion to/from `xla::Literal` happens at the
//! [`super::engine`] boundary. Data is stored in natural typed vectors so
//! the gradient all-reduce can operate on `&mut [f32]` without casts.
//!
//! Owned vs shared payloads: each dtype has an owned `Vec` variant and a
//! [`SharedBuf`] variant. Shared tensors alias a pooled batch buffer
//! (`loader`'s `x_u8`/`labels`/`flip`) — constructing one moves an `Arc`,
//! never payload bytes, which is how the preprocess call stays inside the
//! one-copy invariant (DESIGN.md §2/§7). Shared tensors are read-only:
//! `as_f32_mut` on one is an error by design.

use super::manifest::{DType, TensorSpec};
use crate::util::SharedBuf;
use anyhow::{bail, ensure, Result};

/// Typed tensor payload — owned or aliasing a pooled batch buffer.
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
    F32Shared(SharedBuf<f32>),
    I32Shared(SharedBuf<i32>),
    U8Shared(SharedBuf<u8>),
}

impl Data {
    fn f32s(&self) -> Option<&[f32]> {
        match self {
            Data::F32(v) => Some(v),
            Data::F32Shared(s) => Some(s.as_slice()),
            _ => None,
        }
    }

    fn i32s(&self) -> Option<&[i32]> {
        match self {
            Data::I32(v) => Some(v),
            Data::I32Shared(s) => Some(s.as_slice()),
            _ => None,
        }
    }

    fn u8s(&self) -> Option<&[u8]> {
        match self {
            Data::U8(v) => Some(v),
            Data::U8Shared(s) => Some(s.as_slice()),
            _ => None,
        }
    }
}

/// Payload equality is by dtype + contents: an owned tensor equals a
/// shared one holding the same bytes.
impl PartialEq for Data {
    fn eq(&self, other: &Self) -> bool {
        if let (Some(a), Some(b)) = (self.f32s(), other.f32s()) {
            return a == b;
        }
        if let (Some(a), Some(b)) = (self.i32s(), other.i32s()) {
            return a == b;
        }
        if let (Some(a), Some(b)) = (self.u8s(), other.u8s()) {
            return a == b;
        }
        false
    }
}

/// A host tensor: shape + typed data.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let t = HostTensor { shape, data: Data::F32(data) };
        t.assert_consistent();
        t
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        let t = HostTensor { shape, data: Data::I32(data) };
        t.assert_consistent();
        t
    }

    pub fn u8(shape: Vec<usize>, data: Vec<u8>) -> Self {
        let t = HostTensor { shape, data: Data::U8(data) };
        t.assert_consistent();
        t
    }

    /// Wrap a shared (pooled) buffer without copying — the tensor aliases
    /// the caller's payload.
    pub fn f32_shared(shape: Vec<usize>, data: SharedBuf<f32>) -> Self {
        let t = HostTensor { shape, data: Data::F32Shared(data) };
        t.assert_consistent();
        t
    }

    pub fn i32_shared(shape: Vec<usize>, data: SharedBuf<i32>) -> Self {
        let t = HostTensor { shape, data: Data::I32Shared(data) };
        t.assert_consistent();
        t
    }

    pub fn u8_shared(shape: Vec<usize>, data: SharedBuf<u8>) -> Self {
        let t = HostTensor { shape, data: Data::U8Shared(data) };
        t.assert_consistent();
        t
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        match spec.dtype {
            DType::F32 => Self::f32(spec.shape.clone(), vec![0.0; spec.elements()]),
            DType::I32 => Self::i32(spec.shape.clone(), vec![0; spec.elements()]),
            DType::U8 => Self::u8(spec.shape.clone(), vec![0; spec.elements()]),
        }
    }

    fn assert_consistent(&self) {
        let n: usize = self.shape.iter().product();
        assert_eq!(n, self.len(), "shape/data mismatch");
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) | Data::F32Shared(_) => DType::F32,
            Data::I32(_) | Data::I32Shared(_) => DType::I32,
            Data::U8(_) | Data::U8Shared(_) => DType::U8,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U8(v) => v.len(),
            Data::F32Shared(s) => s.len(),
            Data::I32Shared(s) => s.len(),
            Data::U8Shared(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self.data.f32s() {
            Some(v) => Ok(v),
            None => bail!("tensor is not f32"),
        }
    }

    /// Mutable f32 access — owned tensors only; a shared (pooled) tensor
    /// may be aliased by other readers and is immutable by contract.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::F32Shared(_) => {
                bail!("tensor aliases a shared pooled buffer; cannot mutate")
            }
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self.data.i32s() {
            Some(v) => Ok(v),
            None => bail!("tensor is not i32"),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self.data.u8s() {
            Some(v) => Ok(v),
            None => bail!("tensor is not u8"),
        }
    }

    /// Scalar accessor (loss values etc.).
    pub fn scalar(&self) -> Result<f32> {
        ensure!(self.len() == 1, "tensor is not a scalar");
        Ok(self.as_f32()?[0])
    }

    /// Raw little-endian bytes (for the Literal boundary).
    pub fn bytes(&self) -> Vec<u8> {
        self.byte_view().into_owned()
    }

    /// Zero-copy byte view on little-endian targets (all supported ones);
    /// this is the runtime-boundary hot path — a grad step moves ~14 MiB
    /// of parameters per learner per call (§Perf). Shared payloads view
    /// the pooled buffer in place.
    pub fn byte_view(&self) -> std::borrow::Cow<'_, [u8]> {
        #[cfg(target_endian = "little")]
        {
            fn view<T>(v: &[T]) -> std::borrow::Cow<'_, [u8]> {
                // SAFETY: u8 has alignment 1; the slice covers exactly the
                // initialized elements; T is a plain number type.
                std::borrow::Cow::Borrowed(unsafe {
                    std::slice::from_raw_parts(
                        v.as_ptr() as *const u8,
                        std::mem::size_of_val(v),
                    )
                })
            }
            match &self.data {
                Data::F32(v) => view(v),
                Data::I32(v) => view(v),
                Data::U8(v) => std::borrow::Cow::Borrowed(v),
                Data::F32Shared(s) => view(s.as_slice()),
                Data::I32Shared(s) => view(s.as_slice()),
                Data::U8Shared(s) => std::borrow::Cow::Borrowed(s.as_slice()),
            }
        }
        #[cfg(not(target_endian = "little"))]
        {
            std::borrow::Cow::Owned(match &self.data {
                Data::F32(v) => {
                    v.iter().flat_map(|x| x.to_le_bytes()).collect()
                }
                Data::I32(v) => {
                    v.iter().flat_map(|x| x.to_le_bytes()).collect()
                }
                Data::U8(v) => v.clone(),
                Data::F32Shared(s) => {
                    s.as_slice().iter().flat_map(|x| x.to_le_bytes()).collect()
                }
                Data::I32Shared(s) => {
                    s.as_slice().iter().flat_map(|x| x.to_le_bytes()).collect()
                }
                Data::U8Shared(s) => s.to_vec(),
            })
        }
    }

    /// Validate against a manifest spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        ensure!(
            self.dtype() == spec.dtype,
            "arg {:?}: dtype {:?} != spec {:?}",
            spec.name,
            self.dtype(),
            spec.dtype
        );
        ensure!(
            self.shape == spec.shape,
            "arg {:?}: shape {:?} != spec {:?}",
            spec.name,
            self.shape,
            spec.shape
        );
        Ok(())
    }

    /// Load a raw little-endian f32 binary (initial parameters).
    pub fn from_f32_file(path: &std::path::Path, shape: Vec<usize>) -> Result<Self> {
        let raw = std::fs::read(path)?;
        let n: usize = shape.iter().product();
        ensure!(
            raw.len() == n * 4,
            "{}: {} bytes but shape {:?} needs {}",
            path.display(),
            raw.len(),
            shape,
            n * 4
        );
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(HostTensor::f32(shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_len(), 24);
        assert_eq!(t.dtype(), DType::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_f32(3.5);
        assert_eq!(s.scalar().unwrap(), 3.5);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn bytes_are_little_endian() {
        let t = HostTensor::i32(vec![2], vec![1, -1]);
        assert_eq!(t.bytes(), vec![1, 0, 0, 0, 255, 255, 255, 255]);
        let f = HostTensor::f32(vec![1], vec![1.0]);
        assert_eq!(f.bytes(), 1.0f32.to_le_bytes().to_vec());
    }

    #[test]
    fn check_against_spec() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![4, 2],
            dtype: DType::F32,
        };
        assert!(HostTensor::f32(vec![4, 2], vec![0.0; 8]).check(&spec).is_ok());
        assert!(HostTensor::f32(vec![2, 4], vec![0.0; 8]).check(&spec).is_err());
        assert!(HostTensor::i32(vec![4, 2], vec![0; 8]).check(&spec).is_err());
        let z = HostTensor::zeros(&spec);
        assert!(z.check(&spec).is_ok());
    }

    #[test]
    fn shared_tensor_aliases_without_copying() {
        // The preprocess one-copy guarantee at the type level: wrapping a
        // shared buffer in a tensor must not move payload bytes — the
        // tensor's view points at the very same allocation.
        let buf = SharedBuf::from_vec((0..=255u8).collect::<Vec<u8>>());
        let base_ptr = buf.as_slice().as_ptr();
        let t = HostTensor::u8_shared(vec![16, 16], buf.clone());
        assert_eq!(t.len(), 256);
        assert_eq!(t.dtype(), DType::U8);
        assert_eq!(t.as_u8().unwrap().as_ptr(), base_ptr, "no payload copy");
        assert_eq!(t.byte_view().as_ptr(), base_ptr, "byte view aliases too");
        // Owned vs shared payload equality is by contents.
        let owned = HostTensor::u8(vec![16, 16], (0..=255u8).collect());
        assert_eq!(t, owned);
        // Cloning the tensor shares the same buffer (Arc bump, no copy).
        let t2 = t.clone();
        assert_eq!(t2.as_u8().unwrap().as_ptr(), base_ptr);
    }

    #[test]
    fn shared_f32_is_readable_but_not_mutable() {
        let buf = SharedBuf::from_vec(vec![1.0f32, 2.0, 3.0]);
        let mut t = HostTensor::f32_shared(vec![3], buf);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert!(t.as_f32_mut().is_err(), "shared payloads are immutable");
        let buf_i = SharedBuf::from_vec(vec![4i32, 5]);
        let ti = HostTensor::i32_shared(vec![2], buf_i);
        assert_eq!(ti.as_i32().unwrap(), &[4, 5]);
        assert_eq!(ti.bytes(), vec![4, 0, 0, 0, 5, 0, 0, 0]);
    }

    #[test]
    fn f32_file_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("dlio-tensor-{}.bin", std::process::id()));
        let vals = [0.5f32, -2.25, 1e-3, 7.0];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = HostTensor::from_f32_file(&path, vec![2, 2]).unwrap();
        assert_eq!(t.as_f32().unwrap(), &vals);
        assert!(HostTensor::from_f32_file(&path, vec![3]).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
