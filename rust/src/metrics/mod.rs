//! Metrics: step/epoch accounting mirroring the paper's measurements
//! (training time vs *waiting time* for data, I/O volumes by source,
//! balance traffic), plus CSV/markdown emitters for EXPERIMENTS.md.

use crate::util::stats::Welford;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Where a sample's bytes came from (accounting mirror of
/// `sampler::Provenance`). The local tier is split mem/disk so the
/// hierarchical cache stack's distinct hit costs stay visible end-to-end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// DRAM tier of the local cache stack.
    LocalCache,
    /// SSD spill tier of the local cache stack (mmap-backed, zero-copy).
    LocalDisk,
    RemoteCache,
    Storage,
}

/// Thread-safe loading counters, shared by all loader workers of a learner.
#[derive(Default)]
pub struct LoadCounters {
    pub storage_bytes: AtomicU64,
    pub remote_bytes: AtomicU64,
    pub local_hits: AtomicU64,
    /// Batch positions served by the local stack's SSD tier.
    pub disk_hits: AtomicU64,
    /// Payload bytes those positions carried (all mmap views — served, not
    /// copied).
    pub disk_bytes: AtomicU64,
    pub remote_hits: AtomicU64,
    pub storage_loads: AtomicU64,
    pub decode_ns: AtomicU64,
    pub preprocess_ns: AtomicU64,
    pub fetch_ns: AtomicU64,
    /// `fetch_batch` invocations (the coalesced path).
    pub batch_fetches: AtomicU64,
    /// Fabric messages sent by `fetch_batch` — one per distinct remote
    /// owner per batch, so `remote_hits / owner_messages` is the remote
    /// coalescing factor.
    pub owner_messages: AtomicU64,
    /// Contiguous storage runs read by `fetch_batch` — one token-bucket
    /// acquire + one range read each, so `storage_loads / storage_runs`
    /// is the storage coalescing factor.
    pub storage_runs: AtomicU64,
    /// Payload bytes copied anywhere between the byte source and the
    /// batch tensor: batch assembly (exactly `record_bytes` per sample)
    /// plus any upstream compaction. The one-copy invariant (DESIGN.md
    /// §2/§7) holds iff `copied_bytes / total_samples == record_bytes` —
    /// preprocessing shares the batch buffer and must add zero.
    pub copied_bytes: AtomicU64,
}

impl LoadCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, source: Source, bytes: u64) {
        self.record_n(source, bytes, 1);
    }

    /// Record `n` samples of `bytes` each served from `source` — used for
    /// duplicated ids within a batch, which are fetched once (one read /
    /// one transfer payload) but served into `n` batch positions, so
    /// `total_samples()` always equals the sum of batch sizes.
    pub fn record_n(&self, source: Source, bytes: u64, n: u64) {
        match source {
            Source::LocalCache => {
                self.local_hits.fetch_add(n, Ordering::Relaxed);
            }
            Source::LocalDisk => {
                self.disk_hits.fetch_add(n, Ordering::Relaxed);
                self.disk_bytes.fetch_add(bytes * n, Ordering::Relaxed);
            }
            Source::RemoteCache => {
                self.remote_hits.fetch_add(n, Ordering::Relaxed);
                self.remote_bytes.fetch_add(bytes * n, Ordering::Relaxed);
            }
            Source::Storage => {
                self.storage_loads.fetch_add(n, Ordering::Relaxed);
                self.storage_bytes.fetch_add(bytes * n, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot(&self) -> LoadSnapshot {
        LoadSnapshot {
            storage_bytes: self.storage_bytes.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
            local_hits: self.local_hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            disk_bytes: self.disk_bytes.load(Ordering::Relaxed),
            remote_hits: self.remote_hits.load(Ordering::Relaxed),
            storage_loads: self.storage_loads.load(Ordering::Relaxed),
            decode_s: self.decode_ns.load(Ordering::Relaxed) as f64 / 1e9,
            preprocess_s: self.preprocess_ns.load(Ordering::Relaxed) as f64
                / 1e9,
            fetch_s: self.fetch_ns.load(Ordering::Relaxed) as f64 / 1e9,
            batch_fetches: self.batch_fetches.load(Ordering::Relaxed),
            owner_messages: self.owner_messages.load(Ordering::Relaxed),
            storage_runs: self.storage_runs.load(Ordering::Relaxed),
            copied_bytes: self.copied_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Immutable snapshot of [`LoadCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LoadSnapshot {
    pub storage_bytes: u64,
    pub remote_bytes: u64,
    pub local_hits: u64,
    pub disk_hits: u64,
    pub disk_bytes: u64,
    pub remote_hits: u64,
    pub storage_loads: u64,
    pub decode_s: f64,
    pub preprocess_s: f64,
    pub fetch_s: f64,
    pub batch_fetches: u64,
    pub owner_messages: u64,
    pub storage_runs: u64,
    pub copied_bytes: u64,
}

impl LoadSnapshot {
    pub fn total_samples(&self) -> u64 {
        self.local_hits + self.disk_hits + self.remote_hits + self.storage_loads
    }

    /// This snapshot with the wall-clock occupancy fields zeroed, leaving
    /// only the fields that must be bit-identical for a given workload
    /// regardless of thread interleaving (hits, bytes, messages, runs).
    /// The overlap-determinism tests compare these: the overlapped remote
    /// path may complete owner transfers in any order, but accounting and
    /// batch contents must not depend on that order.
    pub fn deterministic(&self) -> LoadSnapshot {
        LoadSnapshot {
            decode_s: 0.0,
            preprocess_s: 0.0,
            fetch_s: 0.0,
            ..*self
        }
    }

    /// Mean payload bytes copied per served sample — equals `record_bytes`
    /// exactly when the one-copy invariant holds end-to-end (preprocess
    /// included).
    pub fn bytes_copied_per_sample(&self) -> f64 {
        let n = self.total_samples();
        if n == 0 { 0.0 } else { self.copied_bytes as f64 / n as f64 }
    }

    pub fn delta(&self, earlier: &LoadSnapshot) -> LoadSnapshot {
        LoadSnapshot {
            storage_bytes: self.storage_bytes - earlier.storage_bytes,
            remote_bytes: self.remote_bytes - earlier.remote_bytes,
            local_hits: self.local_hits - earlier.local_hits,
            disk_hits: self.disk_hits - earlier.disk_hits,
            disk_bytes: self.disk_bytes - earlier.disk_bytes,
            remote_hits: self.remote_hits - earlier.remote_hits,
            storage_loads: self.storage_loads - earlier.storage_loads,
            decode_s: self.decode_s - earlier.decode_s,
            preprocess_s: self.preprocess_s - earlier.preprocess_s,
            fetch_s: self.fetch_s - earlier.fetch_s,
            batch_fetches: self.batch_fetches - earlier.batch_fetches,
            owner_messages: self.owner_messages - earlier.owner_messages,
            storage_runs: self.storage_runs - earlier.storage_runs,
            copied_bytes: self.copied_bytes - earlier.copied_bytes,
        }
    }
}

/// Per-learner stall decomposition (DESIGN.md §11): where the time a
/// learner spends NOT training actually goes. The three components are
/// disjoint by construction:
///
/// * `fetch_s` — blocked waiting for sample bytes (loader dequeue /
///   fetch path), the paper's Fig. 1 "waiting for data".
/// * `prep_s` — decode + preprocess occupancy charged to this learner's
///   workers (CPU work, not waiting — but it is time the accelerator
///   sits idle when it leaks onto the critical path).
/// * `barrier_s` — blocked at the gradient rendezvous waiting for
///   slower learners ([`crate::coordinator::GradSync::blocked_s`]): the
///   straggler term, the signature a fault injection run must move.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StallSnapshot {
    pub fetch_s: f64,
    pub prep_s: f64,
    pub barrier_s: f64,
}

impl StallSnapshot {
    /// Total stalled seconds across the three components.
    pub fn total_s(&self) -> f64 {
        self.fetch_s + self.prep_s + self.barrier_s
    }

    /// Sum two learners' stalls (aggregation into `TrainingReport`).
    pub fn merge(&self, other: &StallSnapshot) -> StallSnapshot {
        StallSnapshot {
            fetch_s: self.fetch_s + other.fetch_s,
            prep_s: self.prep_s + other.prep_s,
            barrier_s: self.barrier_s + other.barrier_s,
        }
    }

    /// Share of total stall spent waiting on stragglers — the headline
    /// number a fault-injection run reads.
    pub fn barrier_share(&self) -> f64 {
        let t = self.total_s();
        if t <= 0.0 { 0.0 } else { self.barrier_s / t }
    }
}

/// Elastic-recovery accounting (produced by
/// `coordinator::Membership::snapshot`): membership epochs, death/revival
/// counts, deadline misses observed on critical-path waits, and the
/// worst-case MTTR in steps (deadline-miss detection → first step
/// completed after reconciliation). All zeros on a healthy run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoverySnapshot {
    /// Monotonic membership epoch; bumped on every death and revival.
    pub membership_epoch: u64,
    pub deaths: u64,
    pub revivals: u64,
    /// Deadline misses surfaced as `StallError` and recovered from.
    pub deadline_misses: u64,
    /// Max steps from detection to the first post-reconciliation step.
    pub mttr_steps: u64,
}

/// Hierarchical cache-tier accounting (produced by
/// `CacheStack::tier_snapshot`): mem/disk hit split, spill write-behind
/// occupancy, and
/// the disk-hit zero-copy meter. Aggregated across learners via [`merge`]
/// into `TrainingReport.tiers` and the `BENCH_hotpath.json` cache section.
///
/// [`merge`]: TierSnapshot::merge
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierSnapshot {
    /// Lookups served by the DRAM tier.
    pub mem_hits: u64,
    /// Lookups routed to the SSD tier.
    pub disk_hits: u64,
    /// Lookups that missed both tiers.
    pub misses: u64,
    pub mem_entries: u64,
    pub mem_bytes: u64,
    pub mem_capacity: u64,
    pub disk_entries: u64,
    pub disk_bytes: u64,
    pub disk_capacity: u64,
    /// Payload bytes written into the spill segment (either path).
    pub spill_bytes: u64,
    /// Write-behind spills still queued (instantaneous gauge).
    pub spill_queue_depth: u64,
    /// Peak write-behind queue depth (lifetime gauge; `merge` keeps max).
    pub spill_queue_peak: u64,
    /// Spill writes that ran on the spill executor — off the batch
    /// critical path.
    pub spilled_offpath: u64,
    /// Spill writes that ran inline on the inserting thread (no executor
    /// attached); the benches/CI guard this at 0 for the live pipeline.
    pub spilled_inline: u64,
    pub spill_failures: u64,
    /// Payload bytes materialized from the spill segment (mmap views —
    /// served, not copied), once per *unique* id per batch. `disk_hits`
    /// counts routed lookups (one per batch position), so with duplicated
    /// ids this is deliberately NOT `disk_hits × record_bytes`; the
    /// per-position byte meter is `LoadSnapshot::disk_bytes`.
    pub disk_hit_bytes: u64,
    /// Disk-hit payload bytes that were NOT zero-copy mapped views. Any
    /// nonzero value means the SSD tier broke the one-copy invariant.
    pub disk_hit_copied_bytes: u64,
    /// Inserts every tier rejected.
    pub rejected: u64,
}

impl TierSnapshot {
    pub fn lookups(&self) -> u64 {
        self.mem_hits + self.disk_hits + self.misses
    }

    pub fn mem_hit_ratio(&self) -> f64 {
        let n = self.lookups();
        if n == 0 { 0.0 } else { self.mem_hits as f64 / n as f64 }
    }

    /// Fraction of lookups the SSD tier served — the DRAM-overflow meter
    /// (`cache/disk_hit_ratio` in `BENCH_hotpath.json`).
    pub fn disk_hit_ratio(&self) -> f64 {
        let n = self.lookups();
        if n == 0 { 0.0 } else { self.disk_hits as f64 / n as f64 }
    }

    pub fn hit_ratio(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            0.0
        } else {
            (self.mem_hits + self.disk_hits) as f64 / n as f64
        }
    }

    /// Fraction of spill writes that stayed off the batch critical path;
    /// 1.0 when nothing spilled.
    pub fn spill_offpath_ratio(&self) -> f64 {
        let total = self.spilled_offpath + self.spilled_inline;
        if total == 0 {
            1.0
        } else {
            self.spilled_offpath as f64 / total as f64
        }
    }

    /// Disk-tier share of the resident set — the live pipeline's measured
    /// α_disk/α split feeding the hierarchical Eq. 7 term.
    pub fn disk_share(&self) -> f64 {
        let n = self.mem_entries + self.disk_entries;
        if n == 0 { 0.0 } else { self.disk_entries as f64 / n as f64 }
    }

    /// Combined two-tier resident bytes / capacity.
    pub fn total_bytes(&self) -> u64 {
        self.mem_bytes + self.disk_bytes
    }

    pub fn total_capacity(&self) -> u64 {
        self.mem_capacity.saturating_add(self.disk_capacity)
    }

    /// Sum two stacks' accounting (capacities saturate: an "unbounded"
    /// `u64::MAX` mem tier must not wrap; peaks keep the max).
    pub fn merge(&self, other: &TierSnapshot) -> TierSnapshot {
        TierSnapshot {
            mem_hits: self.mem_hits + other.mem_hits,
            disk_hits: self.disk_hits + other.disk_hits,
            misses: self.misses + other.misses,
            mem_entries: self.mem_entries + other.mem_entries,
            mem_bytes: self.mem_bytes + other.mem_bytes,
            mem_capacity: self.mem_capacity.saturating_add(other.mem_capacity),
            disk_entries: self.disk_entries + other.disk_entries,
            disk_bytes: self.disk_bytes + other.disk_bytes,
            disk_capacity: self
                .disk_capacity
                .saturating_add(other.disk_capacity),
            spill_bytes: self.spill_bytes + other.spill_bytes,
            spill_queue_depth: self.spill_queue_depth
                + other.spill_queue_depth,
            spill_queue_peak: self.spill_queue_peak.max(other.spill_queue_peak),
            spilled_offpath: self.spilled_offpath + other.spilled_offpath,
            spilled_inline: self.spilled_inline + other.spilled_inline,
            spill_failures: self.spill_failures + other.spill_failures,
            disk_hit_bytes: self.disk_hit_bytes + other.disk_hit_bytes,
            disk_hit_copied_bytes: self.disk_hit_copied_bytes
                + other.disk_hit_copied_bytes,
            rejected: self.rejected + other.rejected,
        }
    }
}

/// Fabric overlap/occupancy snapshot ([`crate::net::Fabric::snapshot`]):
/// meters whether remote transfers actually overlap on the link-occupancy
/// fabric (DESIGN.md §9) instead of serializing on one worker thread.
///
/// * `serialized_transfer_s` — the sum of every transfer's charged cost
///   (latency + bytes/bw): what the remote path would cost end-to-end if
///   every transfer ran back-to-back (the pre-overlap behaviour).
/// * `overlapped_wall_s` — real wall time during which at least one
///   transfer was in flight (union of in-flight spans). With k-owner
///   overlap this approaches max-over-owners, so
///   `serialized / overlapped` — [`overlap_ratio`] — is the measured
///   overlap factor (≈1 serialized, →k at full overlap). Only meaningful
///   when the fabric runs `real_time`.
/// * `queue_delay_s` — total time transfers spent queued behind earlier
///   reservations on a contended link (completion − request − cost),
///   split by direction in `egress_queue_s`/`ingress_queue_s`.
///
/// [`overlap_ratio`]: FabricSnapshot::overlap_ratio
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FabricSnapshot {
    pub transfers: u64,
    pub bytes: u64,
    pub serialized_transfer_s: f64,
    pub overlapped_wall_s: f64,
    pub max_transfer_s: f64,
    pub queue_delay_s: f64,
    pub egress_queue_s: f64,
    pub ingress_queue_s: f64,
    /// Peak concurrently in-flight transfers (lifetime gauge; `delta`
    /// keeps the later value, it cannot be windowed).
    pub inflight_peak: u64,
    /// Whether the fabric slept transfers in real time. The wall/queue
    /// gauges are physical measurements only when true — virtual mode
    /// anchors reservations to the request clock without sleeping, so
    /// there they are relative indicators at best. Traffic counters
    /// (transfers, bytes, serialized seconds) are exact in both modes.
    pub real_time: bool,
}

impl FabricSnapshot {
    /// Measured overlap factor: charged transfer seconds per wall second
    /// of transfer activity. 0 when nothing was in flight long enough to
    /// measure — or when the fabric ran virtual (no sleeps, so no wall
    /// measurement exists to divide by).
    pub fn overlap_ratio(&self) -> f64 {
        if !self.real_time || self.overlapped_wall_s <= 0.0 {
            0.0
        } else {
            self.serialized_transfer_s / self.overlapped_wall_s
        }
    }

    /// Mean queueing delay per transfer.
    pub fn queue_delay_per_transfer_s(&self) -> f64 {
        if self.transfers == 0 {
            0.0
        } else {
            self.queue_delay_s / self.transfers as f64
        }
    }

    pub fn delta(&self, earlier: &FabricSnapshot) -> FabricSnapshot {
        FabricSnapshot {
            transfers: self.transfers - earlier.transfers,
            bytes: self.bytes - earlier.bytes,
            serialized_transfer_s: self.serialized_transfer_s
                - earlier.serialized_transfer_s,
            overlapped_wall_s: self.overlapped_wall_s
                - earlier.overlapped_wall_s,
            max_transfer_s: self.max_transfer_s,
            queue_delay_s: self.queue_delay_s - earlier.queue_delay_s,
            egress_queue_s: self.egress_queue_s - earlier.egress_queue_s,
            ingress_queue_s: self.ingress_queue_s - earlier.ingress_queue_s,
            inflight_peak: self.inflight_peak,
            real_time: self.real_time,
        }
    }
}

/// Storage-engine snapshot ([`crate::storage::StorageSystem::storage_snapshot`]):
/// meters the batched async submission path (DESIGN.md §15) — how deep the
/// waves run, whether the modeled per-request storage latency actually
/// overlapped, and where the landed pages sat relative to the consuming
/// NUMA node.
///
/// * `serialized_storage_s` — modeled per-request service latency summed
///   as if every run in every wave paid it back-to-back (the blocking
///   baseline). Zero when `storage_latency_s` is unset.
/// * `overlapped_storage_s` — the same latency as actually charged: once
///   per submission wave on the async path, once per run on the blocking
///   path. `serialized / overlapped` — [`overlap_ratio`] — is therefore
///   ≈1 for blocking reads and →(runs per wave) at full submission-wave
///   overlap.
///
/// [`overlap_ratio`]: StorageSnapshot::overlap_ratio
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StorageSnapshot {
    /// Submission waves begun (`read_batch_begin` calls).
    pub waves: u64,
    /// SQEs pushed to the uring backend (0 on the pread fallback).
    pub sqes: u64,
    /// CQEs reaped from the uring backend.
    pub cqes: u64,
    /// Peak runs submitted in a single wave (lifetime gauge; `delta`
    /// keeps the later value, it cannot be windowed).
    pub wave_depth_peak: u64,
    /// Peak concurrently in-flight uring reads across all waves.
    pub inflight_peak: u64,
    pub serialized_storage_s: f64,
    pub overlapped_storage_s: f64,
    /// Whether the uring backend is live (false: mmap/pread fallback).
    pub engine_uring: bool,
    /// 4 KiB pages landed on the consuming learner's NUMA node (or
    /// unattributable — single-node hosts count everything here).
    pub local_pages: u64,
    /// Pages landed on a *different* node than the learner they serve.
    pub cross_node_pages: u64,
    /// NUMA nodes the placement policy saw (1 = no topology / no pinning).
    pub numa_nodes: u64,
}

impl StorageSnapshot {
    /// Measured submission-wave overlap factor: modeled serialized storage
    /// seconds per charged second. 0 when no latency model is configured
    /// (`storage_latency_s = 0`), so "not modeled" is distinguishable
    /// from "no overlap" (≈1).
    pub fn overlap_ratio(&self) -> f64 {
        if self.overlapped_storage_s <= 0.0 {
            0.0
        } else {
            self.serialized_storage_s / self.overlapped_storage_s
        }
    }

    /// Fraction of landed pages that crossed a NUMA boundary.
    pub fn cross_node_page_ratio(&self) -> f64 {
        let total = self.local_pages + self.cross_node_pages;
        if total == 0 {
            0.0
        } else {
            self.cross_node_pages as f64 / total as f64
        }
    }

    pub fn delta(&self, earlier: &StorageSnapshot) -> StorageSnapshot {
        StorageSnapshot {
            waves: self.waves - earlier.waves,
            sqes: self.sqes - earlier.sqes,
            cqes: self.cqes - earlier.cqes,
            wave_depth_peak: self.wave_depth_peak,
            inflight_peak: self.inflight_peak,
            serialized_storage_s: self.serialized_storage_s
                - earlier.serialized_storage_s,
            overlapped_storage_s: self.overlapped_storage_s
                - earlier.overlapped_storage_s,
            engine_uring: self.engine_uring,
            local_pages: self.local_pages - earlier.local_pages,
            cross_node_pages: self.cross_node_pages
                - earlier.cross_node_pages,
            numa_nodes: self.numa_nodes,
        }
    }
}

/// Counters for the shared epoch-partition planner
/// ([`crate::sampler::PartitionPlanner`]): one planner per process computes
/// each step's partition once on a background thread; these meter that the
/// partition work stays off the training critical path.
#[derive(Default)]
pub struct PlannerCounters {
    /// Step plans published by the background planner thread.
    pub plans_published: AtomicU64,
    /// Nanoseconds the background thread spent computing plans (off the
    /// training critical path by construction).
    pub plan_ns: AtomicU64,
    /// Nanoseconds learner threads spent blocked in `get` waiting for a
    /// plan — the only partition cost that can reach the critical path.
    pub get_wait_ns: AtomicU64,
    /// Plan requests served without blocking (plan already published).
    pub gets_immediate: AtomicU64,
    /// Plan requests that had to block until the planner caught up.
    pub gets_blocked: AtomicU64,
    /// Partitions recomputed synchronously on a *calling* (training)
    /// thread: ticked when a plan is requested after the board retired it
    /// — i.e. some thread consumed a step's plan more than once, the
    /// legacy per-step double-compute pattern. The planner serves such
    /// requests by recomputing inline, so this meters exactly the work
    /// the planner exists to remove; `hotpath_micro`/CI assert zero.
    pub critical_path_recomputes: AtomicU64,
    /// Sum over publishes of how many steps ahead of the fully-consumed
    /// frontier the planner was (mean lead = sum / plans_published).
    pub lead_steps_sum: AtomicU64,
    /// Peak lead observed at publish time.
    pub lead_steps_peak: AtomicU64,
    /// Peak bytes held by published, not-yet-retired plan arenas.
    pub arena_bytes_peak: AtomicU64,
    /// Epoch plans (shared permutations) built — one per epoch per process.
    pub epochs_planned: AtomicU64,
}

impl PlannerCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Monotonic max update for the peak gauges.
    pub fn raise_peak(gauge: &AtomicU64, value: u64) {
        gauge.fetch_max(value, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> PlannerSnapshot {
        PlannerSnapshot {
            plans_published: self.plans_published.load(Ordering::Relaxed),
            plan_s: self.plan_ns.load(Ordering::Relaxed) as f64 / 1e9,
            get_wait_s: self.get_wait_ns.load(Ordering::Relaxed) as f64 / 1e9,
            gets_immediate: self.gets_immediate.load(Ordering::Relaxed),
            gets_blocked: self.gets_blocked.load(Ordering::Relaxed),
            critical_path_recomputes: self
                .critical_path_recomputes
                .load(Ordering::Relaxed),
            lead_steps_sum: self.lead_steps_sum.load(Ordering::Relaxed),
            lead_steps_peak: self.lead_steps_peak.load(Ordering::Relaxed),
            arena_bytes_peak: self.arena_bytes_peak.load(Ordering::Relaxed),
            epochs_planned: self.epochs_planned.load(Ordering::Relaxed),
        }
    }
}

/// Immutable snapshot of [`PlannerCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlannerSnapshot {
    pub plans_published: u64,
    pub plan_s: f64,
    pub get_wait_s: f64,
    pub gets_immediate: u64,
    pub gets_blocked: u64,
    pub critical_path_recomputes: u64,
    pub lead_steps_sum: u64,
    pub lead_steps_peak: u64,
    pub arena_bytes_peak: u64,
    pub epochs_planned: u64,
}

impl PlannerSnapshot {
    /// Mean steps of lead the planner held at publish time.
    pub fn mean_lead_steps(&self) -> f64 {
        if self.plans_published == 0 {
            0.0
        } else {
            self.lead_steps_sum as f64 / self.plans_published as f64
        }
    }

    /// Fraction of plan requests that found their plan already published.
    pub fn immediate_share(&self) -> f64 {
        let total = self.gets_immediate + self.gets_blocked;
        if total == 0 {
            1.0
        } else {
            self.gets_immediate as f64 / total as f64
        }
    }
}

/// Per-epoch report — one row of Fig. 1/8/12-style output.
#[derive(Clone, Debug, Default)]
pub struct EpochReport {
    pub epoch: u64,
    pub steps: usize,
    /// Wall-clock epoch time.
    pub epoch_time_s: f64,
    /// Time learners spent blocked waiting for data (paper Fig. 1 blue).
    pub wait_time_s: f64,
    /// Time in the compiled training step (paper Fig. 1 orange).
    pub train_time_s: f64,
    /// Time in gradient synchronization.
    pub sync_time_s: f64,
    pub load: LoadSnapshot,
    pub mean_loss: f64,
    pub accuracy: Option<f64>,
    /// Samples moved for balancing this epoch (Loc only).
    pub balance_moves: u64,
    /// Samples whose gradients entered the reduction this epoch —
    /// adopted shares included, so exactly-once holds iff this equals the
    /// epoch's planned sample count even under chaos.
    pub trained_samples: u64,
    /// Order-independent multiset digest of the grad-consumed sample ids
    /// (wrapping sum of a per-id mix). Chaos and clean runs of the same
    /// schedule must agree per epoch: same value ⟺ same samples trained,
    /// no loss, no duplication.
    pub sample_digest: u64,
}

impl EpochReport {
    pub fn markdown_header() -> &'static str {
        "| epoch | steps | epoch s | wait s | train s | sync s | loss | \
         storage MiB | remote MiB | local hits | acc |\n\
         |---|---|---|---|---|---|---|---|---|---|---|"
    }

    pub fn markdown_row(&self) -> String {
        format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.4} | {:.2} | {:.2} | {} | {} |",
            self.epoch,
            self.steps,
            self.epoch_time_s,
            self.wait_time_s,
            self.train_time_s,
            self.sync_time_s,
            self.mean_loss,
            self.load.storage_bytes as f64 / (1024.0 * 1024.0),
            self.load.remote_bytes as f64 / (1024.0 * 1024.0),
            self.load.local_hits,
            self.accuracy
                .map(|a| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "-".into()),
        )
    }

    pub fn csv_header() -> &'static str {
        "epoch,steps,epoch_s,wait_s,train_s,sync_s,loss,storage_bytes,\
         remote_bytes,local_hits,disk_hits,remote_hits,storage_loads,\
         accuracy,balance_moves,trained_samples,sample_digest"
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{},{},{},{},{},{},{},{},{},{}",
            self.epoch,
            self.steps,
            self.epoch_time_s,
            self.wait_time_s,
            self.train_time_s,
            self.sync_time_s,
            self.mean_loss,
            self.load.storage_bytes,
            self.load.remote_bytes,
            self.load.local_hits,
            self.load.disk_hits,
            self.load.remote_hits,
            self.load.storage_loads,
            self.accuracy.map(|a| a.to_string()).unwrap_or_default(),
            self.balance_moves,
            self.trained_samples,
            self.sample_digest,
        )
    }
}

/// Shared accumulator of per-step timings across learner threads.
#[derive(Default)]
pub struct StepTimes {
    pub wait: Mutex<Welford>,
    pub train: Mutex<Welford>,
    pub sync: Mutex<Welford>,
}

impl StepTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, wait_s: f64, train_s: f64, sync_s: f64) {
        self.wait.lock().unwrap().push(wait_s);
        self.train.lock().unwrap().push(train_s);
        self.sync.lock().unwrap().push(sync_s);
    }

    /// (total wait, total train, total sync) across recorded steps.
    pub fn totals(&self) -> (f64, f64, f64) {
        let w = self.wait.lock().unwrap();
        let t = self.train.lock().unwrap();
        let s = self.sync.lock().unwrap();
        (
            w.mean() * w.count() as f64,
            t.mean() * t.count() as f64,
            s.mean() * s.count() as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_record_by_source() {
        let c = LoadCounters::new();
        c.record(Source::LocalCache, 100);
        c.record(Source::RemoteCache, 200);
        c.record(Source::Storage, 300);
        c.record(Source::Storage, 300);
        let s = c.snapshot();
        assert_eq!(s.local_hits, 1);
        assert_eq!(s.remote_hits, 1);
        assert_eq!(s.storage_loads, 2);
        assert_eq!(s.remote_bytes, 200);
        assert_eq!(s.storage_bytes, 600);
        assert_eq!(s.total_samples(), 4);
    }

    #[test]
    fn snapshot_delta() {
        let c = LoadCounters::new();
        c.record(Source::Storage, 50);
        let a = c.snapshot();
        c.record(Source::Storage, 70);
        c.record(Source::LocalCache, 0);
        let b = c.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.storage_bytes, 70);
        assert_eq!(d.storage_loads, 1);
        assert_eq!(d.local_hits, 1);
    }

    #[test]
    fn record_n_multiplies_counts_and_bytes() {
        let c = LoadCounters::new();
        c.record_n(Source::RemoteCache, 100, 3);
        c.record_n(Source::Storage, 50, 2);
        c.record_n(Source::LocalCache, 0, 4);
        let s = c.snapshot();
        assert_eq!(s.remote_hits, 3);
        assert_eq!(s.remote_bytes, 300);
        assert_eq!(s.storage_loads, 2);
        assert_eq!(s.storage_bytes, 100);
        assert_eq!(s.local_hits, 4);
        assert_eq!(s.total_samples(), 9);
    }

    #[test]
    fn coalescing_counters_snapshot_and_delta() {
        let c = LoadCounters::new();
        c.batch_fetches.fetch_add(2, Ordering::Relaxed);
        c.owner_messages.fetch_add(3, Ordering::Relaxed);
        let a = c.snapshot();
        assert_eq!(a.batch_fetches, 2);
        assert_eq!(a.owner_messages, 3);
        assert_eq!(a.storage_runs, 0);
        c.storage_runs.fetch_add(5, Ordering::Relaxed);
        c.batch_fetches.fetch_add(1, Ordering::Relaxed);
        let d = c.snapshot().delta(&a);
        assert_eq!(d.batch_fetches, 1);
        assert_eq!(d.owner_messages, 0);
        assert_eq!(d.storage_runs, 5);
    }

    #[test]
    fn copied_bytes_feed_the_one_copy_check() {
        let c = LoadCounters::new();
        c.record_n(Source::Storage, 3072, 4);
        c.copied_bytes.fetch_add(4 * 3072, Ordering::Relaxed);
        let a = c.snapshot();
        assert_eq!(a.copied_bytes, 4 * 3072);
        assert!((a.bytes_copied_per_sample() - 3072.0).abs() < 1e-9);
        c.record(Source::LocalCache, 3072);
        c.copied_bytes.fetch_add(3072, Ordering::Relaxed);
        let d = c.snapshot().delta(&a);
        assert_eq!(d.copied_bytes, 3072);
        assert!((d.bytes_copied_per_sample() - 3072.0).abs() < 1e-9);
        assert_eq!(LoadSnapshot::default().bytes_copied_per_sample(), 0.0);
    }

    #[test]
    fn deterministic_view_zeroes_wall_clock_fields() {
        let c = LoadCounters::new();
        c.record(Source::Storage, 100);
        c.fetch_ns.fetch_add(1234, Ordering::Relaxed);
        c.decode_ns.fetch_add(999, Ordering::Relaxed);
        let s = c.snapshot();
        let d = s.deterministic();
        assert_eq!(d.fetch_s, 0.0);
        assert_eq!(d.decode_s, 0.0);
        assert_eq!(d.preprocess_s, 0.0);
        assert_eq!(d.storage_loads, 1);
        assert_eq!(d.storage_bytes, 100);
        // Two equal workloads compare equal regardless of timing.
        assert_eq!(d, s.deterministic());
    }

    #[test]
    fn local_disk_source_feeds_the_hierarchy_split() {
        let c = LoadCounters::new();
        c.record_n(Source::LocalCache, 3072, 2);
        c.record_n(Source::LocalDisk, 3072, 3);
        c.record(Source::Storage, 3072);
        let s = c.snapshot();
        assert_eq!(s.local_hits, 2);
        assert_eq!(s.disk_hits, 3);
        assert_eq!(s.disk_bytes, 3 * 3072);
        assert_eq!(s.total_samples(), 6);
        let d = c.snapshot().delta(&s);
        assert_eq!(d.disk_hits, 0);
        assert_eq!(d.disk_bytes, 0);
        c.record(Source::LocalDisk, 100);
        let d = c.snapshot().delta(&s);
        assert_eq!(d.disk_hits, 1);
        assert_eq!(d.disk_bytes, 100);
    }

    #[test]
    fn stall_snapshot_totals_and_merge() {
        let a = StallSnapshot { fetch_s: 0.2, prep_s: 0.1, barrier_s: 0.7 };
        assert!((a.total_s() - 1.0).abs() < 1e-12);
        assert!((a.barrier_share() - 0.7).abs() < 1e-12);
        let b = StallSnapshot { fetch_s: 0.1, prep_s: 0.0, barrier_s: 0.1 };
        let m = a.merge(&b);
        assert!((m.fetch_s - 0.3).abs() < 1e-12);
        assert!((m.barrier_s - 0.8).abs() < 1e-12);
        assert_eq!(StallSnapshot::default().barrier_share(), 0.0);
        assert_eq!(StallSnapshot::default().total_s(), 0.0);
    }

    #[test]
    fn tier_snapshot_ratios_and_merge() {
        let a = TierSnapshot {
            mem_hits: 6,
            disk_hits: 3,
            misses: 1,
            mem_entries: 4,
            mem_bytes: 400,
            mem_capacity: u64::MAX,
            disk_entries: 2,
            disk_bytes: 200,
            disk_capacity: 1000,
            spill_bytes: 200,
            spill_queue_depth: 0,
            spill_queue_peak: 5,
            spilled_offpath: 2,
            spilled_inline: 0,
            spill_failures: 0,
            disk_hit_bytes: 300,
            disk_hit_copied_bytes: 0,
            rejected: 1,
        };
        assert_eq!(a.lookups(), 10);
        assert!((a.mem_hit_ratio() - 0.6).abs() < 1e-12);
        assert!((a.disk_hit_ratio() - 0.3).abs() < 1e-12);
        assert!((a.hit_ratio() - 0.9).abs() < 1e-12);
        assert_eq!(a.spill_offpath_ratio(), 1.0);
        assert!((a.disk_share() - 2.0 / 6.0).abs() < 1e-12);
        assert_eq!(a.total_bytes(), 600);
        // Unbounded mem capacity saturates instead of wrapping.
        assert_eq!(a.total_capacity(), u64::MAX);
        let b = TierSnapshot {
            spilled_inline: 2,
            spilled_offpath: 2,
            spill_queue_peak: 3,
            mem_capacity: 50,
            ..TierSnapshot::default()
        };
        assert!((b.spill_offpath_ratio() - 0.5).abs() < 1e-12);
        let m = a.merge(&b);
        assert_eq!(m.mem_hits, 6);
        assert_eq!(m.spilled_offpath, 4);
        assert_eq!(m.spilled_inline, 2);
        assert_eq!(m.spill_queue_peak, 5, "peaks merge as max");
        assert_eq!(m.mem_capacity, u64::MAX, "capacity merge saturates");
        // Defaults are safe on empty stacks.
        let z = TierSnapshot::default();
        assert_eq!(z.disk_hit_ratio(), 0.0);
        assert_eq!(z.spill_offpath_ratio(), 1.0);
        assert_eq!(z.disk_share(), 0.0);
    }

    #[test]
    fn fabric_snapshot_ratio_and_delta() {
        let a = FabricSnapshot {
            transfers: 2,
            bytes: 100,
            serialized_transfer_s: 0.4,
            overlapped_wall_s: 0.1,
            max_transfer_s: 0.2,
            queue_delay_s: 0.05,
            egress_queue_s: 0.05,
            ingress_queue_s: 0.0,
            inflight_peak: 3,
            real_time: true,
        };
        assert!((a.overlap_ratio() - 4.0).abs() < 1e-12);
        assert!((a.queue_delay_per_transfer_s() - 0.025).abs() < 1e-12);
        // A virtual-mode snapshot never reports a wall-derived ratio.
        let v = FabricSnapshot { real_time: false, ..a };
        assert_eq!(v.overlap_ratio(), 0.0);
        assert_eq!(FabricSnapshot::default().overlap_ratio(), 0.0);
        assert_eq!(
            FabricSnapshot::default().queue_delay_per_transfer_s(),
            0.0
        );
        let b = FabricSnapshot {
            transfers: 5,
            bytes: 300,
            serialized_transfer_s: 1.0,
            overlapped_wall_s: 0.3,
            max_transfer_s: 0.25,
            queue_delay_s: 0.15,
            egress_queue_s: 0.1,
            ingress_queue_s: 0.05,
            inflight_peak: 4,
            real_time: true,
        };
        let d = b.delta(&a);
        assert_eq!(d.transfers, 3);
        assert_eq!(d.bytes, 200);
        assert!((d.serialized_transfer_s - 0.6).abs() < 1e-12);
        assert!((d.overlapped_wall_s - 0.2).abs() < 1e-12);
        assert!((d.overlap_ratio() - 3.0).abs() < 1e-12);
        // Peaks are lifetime gauges: the delta keeps the later value.
        assert_eq!(d.inflight_peak, 4);
        assert_eq!(d.max_transfer_s, 0.25);
    }

    #[test]
    fn storage_snapshot_ratios_and_delta() {
        let a = StorageSnapshot {
            waves: 2,
            sqes: 10,
            cqes: 10,
            wave_depth_peak: 6,
            inflight_peak: 8,
            serialized_storage_s: 0.6,
            overlapped_storage_s: 0.2,
            engine_uring: true,
            local_pages: 90,
            cross_node_pages: 10,
            numa_nodes: 2,
        };
        assert!((a.overlap_ratio() - 3.0).abs() < 1e-12);
        assert!((a.cross_node_page_ratio() - 0.1).abs() < 1e-12);
        // No latency model configured => "not modeled", not "no overlap".
        assert_eq!(StorageSnapshot::default().overlap_ratio(), 0.0);
        assert_eq!(StorageSnapshot::default().cross_node_page_ratio(), 0.0);
        let b = StorageSnapshot {
            waves: 5,
            sqes: 22,
            cqes: 22,
            wave_depth_peak: 7,
            inflight_peak: 9,
            serialized_storage_s: 1.2,
            overlapped_storage_s: 0.3,
            engine_uring: true,
            local_pages: 150,
            cross_node_pages: 30,
            numa_nodes: 2,
        };
        let d = b.delta(&a);
        assert_eq!(d.waves, 3);
        assert_eq!(d.sqes, 12);
        assert_eq!(d.cqes, 12);
        assert!((d.serialized_storage_s - 0.6).abs() < 1e-12);
        assert!((d.overlapped_storage_s - 0.1).abs() < 1e-12);
        assert!((d.overlap_ratio() - 6.0).abs() < 1e-12);
        assert_eq!(d.local_pages, 60);
        assert_eq!(d.cross_node_pages, 20);
        // Peaks are lifetime gauges: the delta keeps the later value.
        assert_eq!(d.wave_depth_peak, 7);
        assert_eq!(d.inflight_peak, 9);
    }

    #[test]
    fn planner_counters_snapshot_and_derived() {
        let c = PlannerCounters::new();
        assert_eq!(c.snapshot().critical_path_recomputes, 0);
        assert_eq!(c.snapshot().immediate_share(), 1.0);
        c.plans_published.fetch_add(4, Ordering::Relaxed);
        c.lead_steps_sum.fetch_add(8, Ordering::Relaxed);
        PlannerCounters::raise_peak(&c.lead_steps_peak, 3);
        PlannerCounters::raise_peak(&c.lead_steps_peak, 2);
        c.gets_immediate.fetch_add(3, Ordering::Relaxed);
        c.gets_blocked.fetch_add(1, Ordering::Relaxed);
        c.plan_ns.fetch_add(2_000_000_000, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.plans_published, 4);
        assert_eq!(s.lead_steps_peak, 3, "peak is a monotonic max");
        assert!((s.mean_lead_steps() - 2.0).abs() < 1e-12);
        assert!((s.immediate_share() - 0.75).abs() < 1e-12);
        assert!((s.plan_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn report_rows_render() {
        let r = EpochReport {
            epoch: 2,
            steps: 10,
            epoch_time_s: 1.5,
            mean_loss: 0.42,
            accuracy: Some(0.875),
            ..Default::default()
        };
        let md = r.markdown_row();
        assert!(md.contains("| 2 |"));
        assert!(md.contains("87.5%"));
        let csv = r.csv_row();
        assert!(csv.starts_with("2,10,"));
        assert_eq!(
            csv.split(',').count(),
            EpochReport::csv_header().split(',').count()
        );
    }

    #[test]
    fn recovery_snapshot_is_all_zero_on_healthy_runs() {
        let z = RecoverySnapshot::default();
        assert_eq!(z.membership_epoch, 0);
        assert_eq!(z.deaths, 0);
        assert_eq!(z.revivals, 0);
        assert_eq!(z.deadline_misses, 0);
        assert_eq!(z.mttr_steps, 0);
        assert_eq!(z, RecoverySnapshot::default());
    }

    #[test]
    fn csv_row_carries_exactly_once_accounting() {
        let r = EpochReport {
            epoch: 1,
            trained_samples: 96,
            sample_digest: 0xDEAD_BEEF,
            ..Default::default()
        };
        let csv = r.csv_row();
        assert!(csv.ends_with(&format!(",96,{}", 0xDEAD_BEEFu64)));
    }

    #[test]
    fn step_times_accumulate() {
        let st = StepTimes::new();
        st.push(0.1, 0.5, 0.05);
        st.push(0.3, 0.5, 0.05);
        let (w, t, s) = st.totals();
        assert!((w - 0.4).abs() < 1e-9);
        assert!((t - 1.0).abs() < 1e-9);
        assert!((s - 0.1).abs() < 1e-9);
    }
}
